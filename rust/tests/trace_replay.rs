//! End-to-end tests for the `mtpp trace` subsystem (docs/traces.md):
//! text sources compile deterministically into the committed `.events`
//! fixtures, generated traces replay bit-identically through the
//! simulator, and the `workload.trace` validation boundary enforces
//! the device-id-space contract.

use std::path::{Path, PathBuf};

use multitascpp::config::spec::ScenarioSpec;
use multitascpp::experiments::common::metrics_snapshot;
use multitascpp::experiments::Ctx;
use multitascpp::trace::{
    compile, generate, parse_text, GenSpec, TextFormat, TraceEvent, TraceFile, TraceShape,
    SAMPLE_NONE,
};

fn repo_path(rel: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join(rel)
}

fn compile_file(rel: &str) -> TraceFile {
    let path = repo_path(rel);
    let text = std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("read {rel}: {e}"));
    let fmt = TextFormat::from_path(&path).unwrap();
    compile(parse_text(fmt, &text).unwrap()).unwrap()
}

fn ctx() -> Ctx {
    Ctx::synthetic(&std::env::temp_dir().join("mtpp_trace_replay_results"), true).unwrap()
}

/// The committed preset `.events` fixtures are exactly what `mtpp
/// trace compile` produces from their committed text sources — the
/// provenance contract docs/traces.md promises (regeneration is
/// `mtpp trace compile <src> -o <out>`).
#[test]
fn committed_fixtures_match_their_text_sources() {
    for (src, events) in [
        ("scenarios/traces/diurnal.csv", "scenarios/traces/diurnal.events"),
        (
            "scenarios/traces/flash-crowd.jsonl",
            "scenarios/traces/flash-crowd.events",
        ),
    ] {
        let compiled = compile_file(src);
        let committed = std::fs::read(repo_path(events)).unwrap();
        assert_eq!(
            compiled.to_bytes(),
            committed,
            "{events} drifted from {src}; regenerate with `mtpp trace compile`"
        );
        // And the committed bytes parse back to the same value.
        assert_eq!(TraceFile::from_bytes(&committed).unwrap(), compiled);
    }
}

/// Compiling the same source twice is byte-identical, and the CSV and
/// JSONL spellings of the same arrival log compile to the same trace.
#[test]
fn compile_is_deterministic_and_format_agnostic() {
    let a = compile_file("rust/tests/fixtures/traces/sample.csv");
    let b = compile_file("rust/tests/fixtures/traces/sample.csv");
    assert_eq!(a.to_bytes(), b.to_bytes());
    let j = compile_file("rust/tests/fixtures/traces/sample.jsonl");
    assert_eq!(a, j, "CSV and JSONL spellings must compile identically");
    assert_eq!(a.to_bytes(), j.to_bytes());
}

/// Replaying a trace preset is bit-deterministic: every recorded
/// arrival becomes exactly one completed sample, and back-to-back runs
/// produce identical metrics snapshots (including the telemetry-trace
/// hash).
#[test]
fn trace_presets_replay_every_arrival_bit_identically() {
    let mut ctx = ctx();
    for preset in ["diurnal-trace", "flash-crowd-trace"] {
        let spec = ScenarioSpec::preset(preset).unwrap();
        let trace = TraceFile::load(&repo_path(spec.workload.trace.as_deref().unwrap())).unwrap();
        let a = ctx.run_spec(&spec).unwrap();
        assert_eq!(
            a.overall.samples,
            trace.events.len(),
            "{preset}: every trace arrival must complete exactly once"
        );
        let b = ctx.run_spec(&spec).unwrap();
        assert_eq!(
            metrics_snapshot(&a),
            metrics_snapshot(&b),
            "{preset}: replay must be bit-deterministic"
        );
    }
}

/// A generated trace replays deterministically through a scenario too
/// (gen -> save -> workload.trace -> run, the full CLI path in-process).
#[test]
fn generated_trace_replays_deterministically() {
    let tf = generate(&GenSpec {
        shape: TraceShape::Bursts,
        devices: 6,
        duration_s: 30.0,
        rate_hz: 1.0,
        seed: 5,
        ..GenSpec::default()
    })
    .unwrap();
    let path = std::env::temp_dir().join("mtpp_trace_replay_bursts.events");
    tf.save(&path).unwrap();
    let mut spec = ScenarioSpec::default();
    spec.set("devices", "low:6").unwrap();
    spec.set("workload.trace", path.to_str().unwrap()).unwrap();
    let mut ctx = ctx();
    let a = ctx.run_spec(&spec).unwrap();
    let b = ctx.run_spec(&spec).unwrap();
    assert_eq!(a.overall.samples, tf.events.len());
    assert_eq!(metrics_snapshot(&a), metrics_snapshot(&b));
}

/// `validate()` is the boundary that rejects a trace whose device-id
/// space exceeds the scenario population — with the path and both
/// counts in the message.
#[test]
fn oversized_trace_rejected_at_validation() {
    let mut spec = ScenarioSpec::default();
    spec.set("devices", "low:4").unwrap();
    let trace_path = repo_path("scenarios/traces/diurnal.events");
    spec.set("workload.trace", trace_path.to_str().unwrap())
        .unwrap();
    let err = spec.validate().unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("0..16") && msg.contains("4 devices"),
        "expected a device-id-space error, got: {msg}"
    );
}

/// Backlogged arrivals (all at t=0) start back-to-back instead of
/// being dropped: sample conservation holds and the run finishes.
#[test]
fn backlogged_arrivals_all_complete() {
    let mut events = Vec::new();
    for i in 0..10u32 {
        events.push(TraceEvent {
            t_ms: 0,
            device: i % 2,
            sample: if i % 3 == 0 { 42 } else { SAMPLE_NONE },
        });
    }
    let tf = TraceFile::new(2, 0, events).unwrap();
    let path = std::env::temp_dir().join("mtpp_trace_replay_backlog.events");
    tf.save(&path).unwrap();
    let mut spec = ScenarioSpec::default();
    spec.set("devices", "low:2").unwrap();
    spec.set("workload.trace", path.to_str().unwrap()).unwrap();
    let m = ctx().run_spec(&spec).unwrap();
    assert_eq!(m.overall.samples, 10, "a t=0 backlog must fully drain");
    assert!(m.makespan_s > 0.0);
}

/// `samples_per_device` is trace-governed in replay mode: changing it
/// does not change what replays.
#[test]
fn samples_per_device_is_ignored_under_replay() {
    let mut spec = ScenarioSpec::preset("diurnal-trace").unwrap();
    let mut ctx = ctx();
    let a = ctx.run_spec(&spec).unwrap();
    spec.set("samples_per_device", "7").unwrap();
    let b = ctx.run_spec(&spec).unwrap();
    assert_eq!(metrics_snapshot(&a), metrics_snapshot(&b));
}
