//! Mirrors rust/src/runtime/par.rs: the one library module allowed to
//! own threads, channels, and join handles (lint carve-out by path).
use std::sync::mpsc::Sender;
use std::thread::JoinHandle;

pub struct Pool {
    pub senders: Vec<Sender<u64>>,
    pub handles: Vec<JoinHandle<()>>,
}
