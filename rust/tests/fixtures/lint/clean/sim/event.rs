//! The one file allowed to hold a BinaryHeap and hand-written float
//! comparators (mirrors rust/src/sim/event.rs's carve-out).
use std::collections::BinaryHeap;

pub struct Queue(pub BinaryHeap<u64>);

pub fn compare(a: f64, b: f64) -> Option<std::cmp::Ordering> {
    a.partial_cmp(&b)
}
