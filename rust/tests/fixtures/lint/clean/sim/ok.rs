//! Everything here skirts a rule without breaking it: forbidden names
//! in comments/strings, contextful panics, id-keyed maps, and a
//! properly reasoned waiver.
use std::collections::BTreeMap;

/* HashMap inside /* a nested block */ comment is inert */
// So is Instant::now or BinaryHeap in a line comment.

#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct ModelId(pub u32);

pub fn good(x: u64, ids: &BTreeMap<ModelId, u64>) {
    assert!(x > 0, "x must be positive, got {x}");
    if ids.is_empty() {
        panic!("no models registered while handling request {x}");
    }
    let banner = "println! and HashMap and Instant::now inside a string";
    let marker = "// mtpp-lint: allow(no-println-in-lib) reason=\"quoted, must not parse\"";
    let raw = r#"eprintln!("SystemTime") in a raw string"#;
    let _ = (banner, marker, raw);
}

// mtpp-lint: allow(no-unordered-maps) reason="demonstration: bounded two-entry scratch map, fully drained each call, never iterated"
pub type Demo = std::collections::HashMap<u8, u8>;

#[cfg(test)]
mod tests {
    #[test]
    fn test_code_may_assert_tersely() {
        assert!(super::ModelId(1) == super::ModelId(1));
    }
}
