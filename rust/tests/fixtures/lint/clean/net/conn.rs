//! net/ owns real I/O threads by design; the threading rule scopes out
//! (it still answers to no-unordered-maps and no-println-in-lib).
use std::sync::mpsc;
use std::thread;

pub fn spawn_reader() {
    let (_tx, _rx) = mpsc::channel::<u64>();
    let _ = thread::spawn(|| {});
}
