//! The binary entry point owns stdout.
pub fn run() {
    println!("cli output is main.rs's job");
}
