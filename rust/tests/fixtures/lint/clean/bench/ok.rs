//! bench/ measures wall-clock by design and prints its own reports.
use std::time::Instant;

pub fn wall() -> f64 {
    let t0 = Instant::now();
    println!("events/sec: measured");
    t0.elapsed().as_secs_f64()
}
