use std::time::Instant;
use std::time::SystemTime;

pub fn measure() -> f64 {
    let t0 = Instant::now();
    t0.elapsed().as_secs_f64()
}
