// mtpp-lint: allow(no-wallclock-in-sim)
pub use std::time::SystemTime;
// mtpp-lint: allow(no-unordered-maps) reason="stale: nothing on the next line uses one"
pub struct Nothing;
// mtpp-lint: allow(made-up-rule) reason="no such rule exists"
pub struct AlsoNothing;
// mtpp-lint allow(missing-the-colon)
pub struct StillNothing;
