use std::collections::HashMap;
use std::collections::HashSet;
use std::collections::BTreeMap;

pub type Tracking = HashMap<u64, f64>;
pub type Seen = HashSet<usize>;
pub type ByName = BTreeMap<String, usize>;
pub type ByRef<'a> = BTreeMap<&'a str, usize>;
pub type ById = BTreeMap<u64, usize>;
