pub fn checks(x: u64, flag: bool) {
    assert!(x > 0);
    debug_assert!(flag);
    if x == 7 {
        panic!("bad state");
    }
    if x == 8 {
        panic!();
    }
    assert!(x < 10, "x out of range: {x}");
    if x == 9 {
        panic!("bad id {x}");
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn message_less_asserts_are_fine_in_tests() {
        assert!(1 + 1 == 2);
    }
}
