use std::collections::BinaryHeap;

pub fn fresh() -> BinaryHeap<u64> {
    BinaryHeap::new()
}
