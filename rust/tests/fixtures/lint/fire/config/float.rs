pub fn sorted(xs: &[f64]) -> Vec<f64> {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    v
}
