use std::thread;
// Ad-hoc threading primitives outside the sanctioned pool module.
use std::sync::Mutex;
use std::sync::RwLock;
use std::sync::atomic::AtomicUsize;
use std::sync::mpsc;
use std::sync::Condvar;
