pub fn noisy(x: u64) {
    println!("x = {x}");
    eprintln!("warning");
}
