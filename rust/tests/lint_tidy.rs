//! Tier-1 tidy gate for `mtpp lint` (docs/linting.md).
//!
//! Two jobs: (1) the shipped tree must be lint-clean — any violation
//! or waiver-hygiene error fails plain `cargo test`, so determinism
//! regressions surface in the PR that introduces them instead of as a
//! golden-trace diff three PRs later; (2) the engine itself is pinned
//! by fixture trees under `rust/tests/fixtures/lint/`: `fire/` lists
//! every (path, line, rule) that must fire, `clean/` exercises the
//! near-misses (strings, comments, carve-out files, reasoned waivers,
//! test regions) that must not.

use std::path::PathBuf;

use multitascpp::lint::lint_tree;

fn repo() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

#[test]
fn shipped_tree_is_lint_clean() {
    let report = lint_tree(&repo().join("rust/src")).expect("scan rust/src");
    assert!(
        report.is_clean(),
        "mtpp lint found violations — fix them or waive with a reason:\n{}",
        report.render_text()
    );
    // Guard against the scan silently finding nothing.
    assert!(
        report.files_scanned > 40,
        "suspiciously few files scanned: {}",
        report.files_scanned
    );
}

#[test]
fn fire_fixtures_fire_exactly_where_expected() {
    let report =
        lint_tree(&repo().join("rust/tests/fixtures/lint/fire")).expect("scan fire fixtures");
    let got: Vec<(&str, u32, &str)> = report
        .violations
        .iter()
        .map(|v| (v.path.as_str(), v.line, v.rule.as_str()))
        .collect();
    let expected: Vec<(&str, u32, &str)> = vec![
        ("config/float.rs", 3, "checked-float-ordering"),
        ("runtime/threads.rs", 1, "no-threading-outside-par"),
        ("runtime/threads.rs", 3, "no-threading-outside-par"),
        ("runtime/threads.rs", 4, "no-threading-outside-par"),
        ("runtime/threads.rs", 5, "no-threading-outside-par"),
        ("runtime/threads.rs", 6, "no-threading-outside-par"),
        ("runtime/threads.rs", 7, "no-threading-outside-par"),
        ("scheduler/heap.rs", 1, "binaryheap-boundary"),
        ("scheduler/heap.rs", 3, "binaryheap-boundary"),
        ("scheduler/heap.rs", 4, "binaryheap-boundary"),
        ("sim/maps.rs", 1, "no-unordered-maps"),
        ("sim/maps.rs", 2, "no-unordered-maps"),
        ("sim/maps.rs", 5, "no-unordered-maps"),
        ("sim/maps.rs", 6, "no-unordered-maps"),
        ("sim/maps.rs", 7, "no-string-model-keys"),
        ("sim/maps.rs", 8, "no-string-model-keys"),
        ("sim/panics.rs", 2, "panic-with-context"),
        ("sim/panics.rs", 3, "panic-with-context"),
        ("sim/panics.rs", 5, "panic-with-context"),
        ("sim/panics.rs", 8, "panic-with-context"),
        // Waiver hygiene: reason-less, stale, unknown rule, malformed.
        ("sim/waivers.rs", 1, "waiver"),
        ("sim/waivers.rs", 3, "waiver"),
        ("sim/waivers.rs", 5, "waiver"),
        ("sim/waivers.rs", 7, "waiver"),
        ("sim/wallclock.rs", 1, "no-wallclock-in-sim"),
        ("sim/wallclock.rs", 2, "no-wallclock-in-sim"),
        ("sim/wallclock.rs", 5, "no-wallclock-in-sim"),
        ("util/print.rs", 2, "no-println-in-lib"),
        ("util/print.rs", 3, "no-println-in-lib"),
    ];
    assert_eq!(got, expected, "\nfull report:\n{}", report.render_text());
}

#[test]
fn clean_fixtures_stay_clean() {
    let report =
        lint_tree(&repo().join("rust/tests/fixtures/lint/clean")).expect("scan clean fixtures");
    assert!(
        report.is_clean(),
        "clean fixture tree must not fire:\n{}",
        report.render_text()
    );
    assert_eq!(report.files_scanned, 6);
}

#[test]
fn json_report_is_parseable_and_ordered() {
    use multitascpp::util::json::Json;
    let report =
        lint_tree(&repo().join("rust/tests/fixtures/lint/fire")).expect("scan fire fixtures");
    let parsed = Json::parse(&report.to_json().pretty(2)).expect("valid JSON");
    let viols = parsed.get("violations").unwrap().as_arr().unwrap();
    assert_eq!(viols.len(), report.violations.len());
    assert_eq!(parsed.get("clean").unwrap().as_bool(), Some(false));
    // Deterministic order: (path, line, rule) ascending.
    let keys: Vec<(String, u32, String)> = viols
        .iter()
        .map(|v| {
            (
                v.str_at("path").unwrap().to_string(),
                v.f64_at("line").unwrap() as u32,
                v.str_at("rule").unwrap().to_string(),
            )
        })
        .collect();
    let mut sorted = keys.clone();
    sorted.sort();
    assert_eq!(keys, sorted);
}
