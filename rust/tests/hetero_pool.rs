//! Regression tests for the heterogeneous replica pool: model-aware
//! dispatch, slack-aware batching, cost-aware autoscaling, the
//! scheduler-path bugfixes that ride along, and seed parity with the
//! PR 1 homogeneous pool.
//!
//! Invariants pinned here:
//! * a homogeneous `--server-models` list is bit-identical to the
//!   default pool (and model-aware dispatch is bit-identical to
//!   lowest-index on any homogeneous pool);
//! * admission-control feasibility uses the *fastest* replica's
//!   batch-1 latency — requests feasible on the fast replica of a
//!   mixed pool are not shed just because replica 0 is slow;
//! * a device resuming from an outage reports its first SR window over
//!   post-resume samples only (stale pre-outage counters are zeroed);
//! * `--wfq-weights` plumb end-to-end and shift per-tier service
//!   shares in the configured direction;
//! * on the PR 1 `replicas` sweep workload, a mixed pool under
//!   model-aware dispatch + slack-aware batching beats lowest-index
//!   dispatch on SLO satisfaction at (near-)equal accuracy;
//! * the autoscaler parks idle capacity in underload and unparks under
//!   pressure without losing samples.

use multitascpp::config::latency::server_latency_model;
use multitascpp::config::scenario::{
    AutoscalePolicy, DispatchKind, Scenario, SchedulerKind, ServerPolicy,
};
use multitascpp::config::SystemConfig;
use multitascpp::data::dataset::Dataset;
use multitascpp::metrics::RunMetrics;
use multitascpp::models::outputs::{OutputProvider, SyntheticOutputs};
use multitascpp::models::registry::test_meta_json;
use multitascpp::models::{Registry, Tier};
use multitascpp::scheduler::{DeviceId, Scheduler, StaticSched, ThresholdUpdate};
use multitascpp::sim::{run_scenario, DeviceSpec, SimEngine};

// --- scenario-level harness (same shape as tests/server_pool.rs) -----------

fn registry() -> Registry {
    Registry::from_meta(std::path::Path::new("/tmp/test_artifacts"), &test_meta_json()).unwrap()
}

fn dataset() -> Dataset {
    Dataset::synthetic_for_tests(5000, 4, 10)
}

fn provider(n: usize) -> SyntheticOutputs {
    SyntheticOutputs::new(
        n,
        &[
            ("dev_low", 0.72),
            ("dev_mid", 0.75),
            ("dev_high", 0.77),
            ("srv_inception", 0.785),
            ("srv_effnetb3", 0.815),
        ],
        42,
    )
}

fn run_with_cfg(scn: &Scenario, cfg: &SystemConfig) -> RunMetrics {
    let reg = registry();
    let ds = dataset();
    let mut prov = provider(ds.n).into_cached();
    run_scenario(scn, cfg, &reg, &ds, &mut prov).unwrap()
}

fn run(scn: &Scenario) -> RunMetrics {
    run_with_cfg(scn, &SystemConfig::default())
}

/// The PR 1 `replicas` sweep workload: overloaded mixed-criticality
/// heterogeneous population under the Static scheduler, so the serving
/// layer — not adaptive thresholds — decides the outcome.
fn mixed_criticality(n: usize, samples: usize) -> Scenario {
    Scenario::heterogeneous(n, "srv_inception")
        .with_scheduler(SchedulerKind::Static)
        .with_slo(150.0)
        .with_tier_slo(Tier::Low, 100.0)
        .with_tier_slo(Tier::High, 400.0)
        .with_samples(samples)
        .with_seed(0)
}

fn assert_bit_identical(a: &RunMetrics, b: &RunMetrics, what: &str) {
    assert_eq!(a.overall.samples, b.overall.samples, "{what}: samples");
    assert_eq!(a.overall.satisfied, b.overall.satisfied, "{what}: satisfied");
    assert_eq!(a.overall.correct, b.overall.correct, "{what}: correct");
    assert_eq!(a.overall.forwarded, b.overall.forwarded, "{what}: forwarded");
    assert_eq!(a.shed, b.shed, "{what}: shed");
    assert_eq!(
        a.per_server_batches, b.per_server_batches,
        "{what}: per-replica batches"
    );
    assert_eq!(
        a.latencies.values(),
        b.latencies.values(),
        "{what}: latency sequence"
    );
    assert!(
        (a.makespan_s - b.makespan_s).abs() < 1e-12,
        "{what}: makespan {} vs {}",
        a.makespan_s,
        b.makespan_s
    );
}

#[test]
fn homogeneous_server_models_list_is_seed_parity() {
    // A homogeneous placement list and the default placement must take
    // the identical code path: same event sequence, same metrics.
    let base = mixed_criticality(12, 300).with_replicas(2);
    let listed = mixed_criticality(12, 300)
        .with_server_models(vec!["srv_inception", "srv_inception"]);
    assert_bit_identical(&run(&base), &run(&listed), "models-list parity");
    // Model-aware dispatch scores every replica of a homogeneous pool
    // identically, so the lowest-index tie-break reproduces the PR 1
    // dispatch rule exactly.
    let lowest = mixed_criticality(12, 300)
        .with_replicas(2)
        .with_dispatch(DispatchKind::LowestIndex);
    assert_bit_identical(&run(&base), &run(&lowest), "dispatch parity");
}

// --- engine-level fixtures for the deterministic regressions ---------------

/// Forwards every sample (BvSB 0 < any threshold); device predictions
/// are always correct so accuracy never confounds the assertions.
struct ForwardAll;

impl OutputProvider for ForwardAll {
    fn device_output(&mut self, _model: &str, _sample: usize) -> (f32, bool) {
        (0.0, true)
    }

    fn server_outputs(&mut self, _model: &str, samples: &[usize]) -> Vec<bool> {
        vec![true; samples.len()]
    }
}

/// Samples below `cut` forward (BvSB 0), the rest complete locally
/// (BvSB 1).
struct SplitProvider {
    cut: usize,
}

impl OutputProvider for SplitProvider {
    fn device_output(&mut self, _model: &str, sample: usize) -> (f32, bool) {
        if sample < self.cut {
            (0.0, true)
        } else {
            (1.0, true)
        }
    }

    fn server_outputs(&mut self, _model: &str, samples: &[usize]) -> Vec<bool> {
        vec![true; samples.len()]
    }
}

/// Records every SR-window update the engine reports.
#[derive(Default)]
struct RecordingSched {
    devices: Vec<(DeviceId, Tier, f64)>,
    srs: Vec<f64>,
}

impl Scheduler for RecordingSched {
    fn register_device(
        &mut self,
        device: DeviceId,
        tier: Tier,
        initial_threshold: f64,
        _sr_target: f64,
    ) -> f64 {
        self.devices.push((device, tier, initial_threshold));
        initial_threshold
    }

    fn on_sr_update(&mut self, _device: DeviceId, sr_percent: f64) -> Option<ThresholdUpdate> {
        self.srs.push(sr_percent);
        None
    }

    fn on_batch_observed(&mut self, _batch_size: usize) -> Vec<ThresholdUpdate> {
        Vec::new()
    }

    fn device_offline(&mut self, _device: DeviceId) {}

    fn device_online(&mut self, _device: DeviceId) {}

    fn threshold(&self, device: DeviceId) -> f64 {
        self.devices
            .iter()
            .find(|(d, _, _)| *d == device)
            .map_or(0.0, |(_, _, c)| *c)
    }

    fn thresholds(&self) -> Vec<(DeviceId, Tier, f64)> {
        self.devices.clone()
    }

    fn name(&self) -> &'static str {
        "recording"
    }
}

fn one_low_device(slo_ms: f64, samples: usize, offline_at: Option<usize>) -> DeviceSpec {
    DeviceSpec {
        tier: Tier::Low,
        stream: (0..samples).collect(),
        arrivals: Vec::new(),
        initial_threshold: 0.5,
        sr_target: 95.0,
        slo_ms,
        offline_at,
        offline_duration_s: 5.0,
    }
}

fn run_engine(
    scheduler: &mut dyn Scheduler,
    provider: &mut dyn OutputProvider,
    policy: &ServerPolicy,
    specs: Vec<DeviceSpec>,
) -> RunMetrics {
    let cfg = SystemConfig::default();
    let latency_of = |m: &str| server_latency_model(m);
    SimEngine::new(
        &cfg,
        scheduler,
        Vec::new(),
        provider,
        &latency_of,
        "srv_inception",
        policy,
        specs,
        0,
    )
    .run()
    .unwrap()
}

/// Regression for the stale `pool.model(0)` admission feasibility: with
/// replica 0 serving the SLOW model, requests that only the fast
/// replica can serve in time must be admitted (min-service = fastest
/// batch-1 latency), and model-aware dispatch must route them there.
///
/// Numbers: low tier t_inf in [28.2, 33.8] ms (±3σ jitter), comm 2 ms,
/// SLO 55 ms, so queue slack at arrival is [19.2, 24.8] ms. InceptionV3
/// batch-1 + return hop = 17.0 ms always fits; EfficientNetB3's 27.1 ms
/// never does. The old replica-0 rule shed every forward.
#[test]
fn admission_feasibility_uses_fastest_replica_of_mixed_pool() {
    let policy = ServerPolicy {
        replicas: 2,
        models: vec!["srv_effnetb3".into(), "srv_inception".into()],
        shed: true,
        ..ServerPolicy::default()
    };
    let mut sched = StaticSched::new();
    let mut prov = ForwardAll;
    let m = run_engine(&mut sched, &mut prov, &policy, vec![one_low_device(55.0, 10, None)]);
    assert_eq!(m.overall.samples, 10);
    assert_eq!(m.shed, 0, "feasible-on-fast-replica requests were shed");
    assert_eq!(m.overall.satisfied, 10, "served via inception => in-SLO");
    // Model-aware dispatch sent every batch to the fast replica (1).
    assert_eq!(m.per_server_batches, vec![0, 10]);
}

/// Companion: under lowest-index dispatch the same workload lands on
/// the slow replica 0, whose formation-time feasibility check culls
/// every request — the serving layer never runs a batch.
#[test]
fn lowest_index_dispatch_strands_mixed_pool_work_on_the_slow_replica() {
    let policy = ServerPolicy {
        replicas: 2,
        models: vec!["srv_effnetb3".into(), "srv_inception".into()],
        shed: true,
        dispatch: DispatchKind::LowestIndex,
        ..ServerPolicy::default()
    };
    let mut sched = StaticSched::new();
    let mut prov = ForwardAll;
    let m = run_engine(&mut sched, &mut prov, &policy, vec![one_low_device(55.0, 10, None)]);
    assert_eq!(m.overall.samples, 10);
    assert_eq!(m.shed, 10, "slow-replica formation should cull everything");
    assert_eq!(m.per_server_batches, vec![0, 0]);
}

/// Regression for the SR-window outage bug: a device resuming from an
/// outage must report its first post-outage window over post-resume
/// samples only. Pre-outage samples here are forwarded misses (latency
/// ~50 ms > 40 ms SLO); post-resume samples are local hits (~31 ms).
/// With stale counters the first update reports ~50%; fixed, every
/// update is 100%.
#[test]
fn sr_window_resets_after_outage() {
    let mut sched = RecordingSched::default();
    let mut prov = SplitProvider { cut: 5 };
    let m = run_engine(
        &mut sched,
        &mut prov,
        &ServerPolicy::default(),
        vec![one_low_device(40.0, 10, Some(5))],
    );
    assert_eq!(m.overall.samples, 10);
    // The mechanism: the 5 forwarded pre-outage samples really did miss
    // their SLO and the 5 post-resume locals made it.
    assert_eq!(m.overall.satisfied, 5);
    assert!(
        !sched.srs.is_empty(),
        "post-resume completions must close an SR window"
    );
    assert!(
        sched.srs.iter().all(|&sr| sr > 99.9),
        "SR updates include stale pre-outage counters: {:?}",
        sched.srs
    );
}

/// CLI-parsed WFQ weights change per-tier service shares end-to-end:
/// two tiers flood a small-batch InceptionV3 queue (grid capped at 4 so
/// pop order, not batch co-residency, decides service), and the favored
/// tier keeps a visibly higher SLO satisfaction in each direction.
#[test]
fn cli_wfq_weights_shift_tier_service_shares() {
    use multitascpp::config::spec::ScenarioSpec;
    // The same dotted paths `mtpp sim` maps `--queue`/`--wfq-weights`
    // onto; validate() assembles the runnable policy.
    let parse = |weights: &str| {
        let mut spec = ScenarioSpec::default();
        spec.set("server.queue", "tier-wfq").unwrap();
        spec.set("server.wfq_weights", weights).unwrap();
        spec.validate().unwrap().server
    };
    let favor_low = parse("low:8,high:1");
    let favor_high = parse("low:1,high:8");
    assert_eq!(favor_low.wfq_weights, [8.0, 1.0, 1.0, 1.0]);
    assert_eq!(favor_high.wfq_weights, [1.0, 1.0, 8.0, 1.0]);

    // Load shape matters: each tier's offered forwards must exceed the
    // DISFAVORED 1/9 share of the ~166/s grid-capped capacity but fit
    // inside the favored 8/9 share (~148/s), so the favored tier is
    // served promptly while the other backlogs. (Far heavier floods
    // would drown both tiers and wash the weight effect out.) The
    // threshold override pins forwarding at the synthetic tables'
    // margin-cap rate (~75%), making each tier's offered load ~90-110/s
    // regardless of the calibrated per-tier thresholds.
    let scenario = |policy: &ServerPolicy| {
        let mut scn = Scenario::homogeneous(Tier::Low, 0, "srv_inception")
            .with_scheduler(SchedulerKind::Static)
            .with_slo(150.0)
            .with_samples(300)
            .with_seed(0)
            .with_server_policy(policy.clone())
            .with_initial_threshold(1.0);
        scn.devices = vec![(Tier::Low, 4), (Tier::High, 4)];
        scn
    };
    let mut cfg = SystemConfig::default();
    cfg.batch_grid = vec![1, 2, 4];
    let a = run_with_cfg(&scenario(&favor_low), &cfg);
    let b = run_with_cfg(&scenario(&favor_high), &cfg);
    assert_eq!(a.overall.samples, 8 * 300);
    assert_eq!(b.overall.samples, 8 * 300);
    let (a_low, a_high) = (
        a.tier(Tier::Low).unwrap().satisfaction_rate(),
        a.tier(Tier::High).unwrap().satisfaction_rate(),
    );
    let (b_low, b_high) = (
        b.tier(Tier::Low).unwrap().satisfaction_rate(),
        b.tier(Tier::High).unwrap().satisfaction_rate(),
    );
    assert!(
        a_low > b_low + 3.0,
        "low tier should gain from low:8 weights: {a_low:.2} vs {b_low:.2}"
    );
    assert!(
        b_high > a_high + 3.0,
        "high tier should gain from high:8 weights: {b_high:.2} vs {a_high:.2}"
    );
}

/// The acceptance-criteria regression: with a mixed
/// EfficientNetB3 + InceptionV3 pool (slow model deliberately on
/// replica 0), model-aware dispatch + slack-aware batching achieves
/// strictly higher SLO satisfaction than lowest-index dispatch, at
/// (near-)equal accuracy.
///
/// The regime makes the gap structural rather than marginal: a 55 ms
/// SLO sits between the two models' served round trips (InceptionV3
/// batch 1-2 lands at ~47-56 ms, EfficientNetB3 at >= 57 ms), so every
/// forward that lowest-index dispatch parks on the slow replica — its
/// deterministic choice whenever both are idle — is a guaranteed miss,
/// while model-aware dispatch serves it in budget, and the slack cap
/// keeps InceptionV3 batches small enough to stay there. Load is light
/// (6 low-tier devices) so queueing noise cannot blur the two.
#[test]
fn model_aware_slack_batching_beats_lowest_index_on_mixed_pool() {
    let mixed = |dispatch: DispatchKind, slack: bool| {
        Scenario::homogeneous(Tier::Low, 6, "srv_inception")
            .with_scheduler(SchedulerKind::Static)
            .with_slo(55.0)
            .with_samples(800)
            .with_seed(0)
            .with_server_policy(ServerPolicy {
                replicas: 2,
                models: vec!["srv_effnetb3".into(), "srv_inception".into()],
                dispatch,
                slack_batch: slack,
                ..ServerPolicy::default()
            })
    };
    let lowest = run(&mixed(DispatchKind::LowestIndex, false));
    let aware = run(&mixed(DispatchKind::ModelAware, true));
    assert_eq!(lowest.overall.samples, aware.overall.samples);
    assert_eq!(lowest.overall.samples, 6 * 800);
    assert!(
        aware.overall.satisfaction_rate() > lowest.overall.satisfaction_rate(),
        "lowest {:.2} vs model-aware+slack {:.2}",
        lowest.overall.satisfaction_rate(),
        aware.overall.satisfaction_rate()
    );
    assert!(
        (aware.overall.accuracy() - lowest.overall.accuracy()).abs() < 0.025,
        "accuracy should be near-equal: lowest {:.4} vs aware {:.4}",
        lowest.overall.accuracy(),
        aware.overall.accuracy()
    );
    // The mechanism: lowest-index keeps feeding the slow replica 0;
    // model-aware routes the bulk of the work to the fast replica 1.
    assert!(
        lowest.per_server_batches[0] > aware.per_server_batches[0],
        "lowest {:?} vs aware {:?}",
        lowest.per_server_batches,
        aware.per_server_batches
    );
    assert!(aware.per_server_batches[1] > lowest.per_server_batches[1]);
}

/// Underload: the autoscaler keeps surplus replicas parked the whole
/// run (reported as parked replica-seconds) without hurting SLO
/// satisfaction or losing samples.
#[test]
fn autoscaler_parks_idle_capacity_in_underload() {
    let scn = Scenario::heterogeneous(6, "srv_inception")
        .with_scheduler(SchedulerKind::Static)
        .with_slo(150.0)
        .with_samples(300)
        .with_seed(0)
        .with_replicas(3)
        .with_autoscale(AutoscalePolicy::default());
    let m = run(&scn);
    assert_eq!(m.overall.samples, 6 * 300);
    assert!(
        m.parked_replica_seconds > 0.0,
        "surplus replicas should stay parked in underload"
    );
    assert!(
        m.trace.iter().any(|p| p.parked_servers > 0),
        "trace should expose parked replicas"
    );
    assert!(
        m.overall.satisfaction_rate() > 90.0,
        "one active replica covers this load: SR {:.2}",
        m.overall.satisfaction_rate()
    );
}

/// Overload: starting from min_active, queue-pressure watermarks unpark
/// the parked replicas, recovering most of the always-on pool's SLO
/// satisfaction — far above a single replica.
#[test]
fn autoscaler_unparks_under_queue_pressure() {
    let base = mixed_criticality(60, 400);
    let single = run(&base.clone().with_replicas(1));
    let scaled_scn = base
        .clone()
        .with_replicas(4)
        .with_autoscale(AutoscalePolicy::default());
    let scaled = run(&scaled_scn);
    assert_eq!(single.overall.samples, scaled.overall.samples);
    assert!(scaled.scale_events >= 1, "overload must trigger scale-ups");
    assert!(
        scaled.parked_replica_seconds > 0.0,
        "ramp-up time counts as parked savings"
    );
    assert!(
        scaled.overall.satisfaction_rate() > single.overall.satisfaction_rate() + 5.0,
        "x1 SR {:.2} vs autoscaled-x4 SR {:.2}",
        single.overall.satisfaction_rate(),
        scaled.overall.satisfaction_rate()
    );
}

/// Smoke for the `hetero-pool` experiment path: every policy in the
/// sweep grid runs to completion on a tiny workload, conserving samples
/// (CI runs this offline; the sweep itself needs artifacts).
#[test]
fn hetero_pool_sweep_policies_smoke() {
    for (label, policy) in multitascpp::experiments::figures::hetero_pool_policies() {
        let scn = mixed_criticality(12, 120).with_server_policy(policy.clone());
        let m = run(&scn);
        assert_eq!(m.overall.samples, 12 * 120, "{label}: sample conservation");
        assert!(
            m.overall.satisfaction_rate().is_finite(),
            "{label}: SR must be finite"
        );
        assert_eq!(
            m.per_server_batches.len(),
            policy.replicas,
            "{label}: replica accounting"
        );
        if policy.autoscale.is_some() {
            assert!(m.parked_replica_seconds >= 0.0, "{label}: parked seconds");
        }
    }
}
