//! Differential property tests: the hierarchical timer-wheel
//! [`EventQueue`] against the reference [`BinaryHeapQueue`] ordering
//! oracle. The two must agree on the exact pop sequence — same (time,
//! event) pairs, in the same order — across randomized schedules that
//! stress every structural path of the wheel:
//!
//! * dense same-time ties (FIFO-by-sequence draining inside one tick),
//! * sub-tick time differences (distinct `f64`s sharing one wheel
//!   tick, where the sorted bucket drain must order by exact time),
//! * far-future outliers (overflow-level filing and the block cascade
//!   when the cursor crosses into a new 2^24-tick window),
//! * interleaved push/pop (pushes landing at or before the advancing
//!   cursor, which must file directly into the due list),
//! * negative times (saturating tick quantization).
//!
//! Plus the shared hard contract: push panics on non-finite times in
//! both implementations.

use multitascpp::sim::event::{BinaryHeapQueue, Event, EventQueue};
use multitascpp::util::prng::Rng;

/// Distinct payloads so a mis-ordered pop cannot masquerade as a tie:
/// the tag rides in the event's `device`/`server` field.
fn ev(tag: usize) -> Event {
    match tag % 4 {
        0 => Event::DeviceInferDone {
            device: tag,
            dur_s: 0.001,
        },
        1 => Event::ServerBatchDone { server: tag },
        2 => Event::SrWindow { device: tag },
        _ => Event::DeviceResume { device: tag },
    }
}

/// One randomized schedule: push/pop both queues in lockstep from the
/// same operation stream and assert identical pop sequences, then
/// drain both and assert the tails match too.
fn run_case(seed: u64, ops: usize, time_profile: &str) {
    let mut rng = Rng::new(seed);
    let mut wheel = EventQueue::new();
    let mut heap = BinaryHeapQueue::new();
    let mut tag = 0usize;
    let mut now = 0.0f64;
    for _ in 0..ops {
        // 2:1 push:pop mix keeps both queues populated while still
        // exercising interleaved pops at every wheel position.
        if rng.next_below(3) < 2 {
            let t = match time_profile {
                // Dense ties: a handful of exact times, many events each.
                "ties" => (rng.next_below(8) as f64) * 0.25,
                // Sub-tick jitter: offsets far smaller than 1/1024 s.
                "subtick" => now + rng.next_below(4) as f64 * 1e-6,
                // Far-future outliers: mostly near-term, occasionally
                // hours out (beyond the 2^24-tick wheel horizon).
                "outliers" => {
                    if rng.next_below(10) == 0 {
                        now + 20_000.0 + rng.next_f64() * 50_000.0
                    } else {
                        now + rng.next_f64() * 2.0
                    }
                }
                // Mild negatives mixed with ordinary times.
                "negative" => now + rng.next_range_f64(-1.5, 3.0),
                _ => unreachable!("unknown profile {time_profile}"),
            };
            let e = ev(tag);
            tag += 1;
            wheel.push(t, e.clone());
            heap.push(t, e);
        } else {
            let a = wheel.pop();
            let b = heap.pop();
            assert_eq!(
                a, b,
                "{time_profile} seed {seed}: wheel and heap disagree mid-stream"
            );
            if let Some((t, _)) = a {
                // Advancing `now` past popped times steers later pushes
                // toward (and behind) the wheel cursor.
                now = now.max(t);
            }
        }
        assert_eq!(wheel.len(), heap.len(), "{time_profile} seed {seed}");
        assert_eq!(wheel.is_empty(), heap.is_empty());
    }
    loop {
        let a = wheel.pop();
        let b = heap.pop();
        assert_eq!(a, b, "{time_profile} seed {seed}: drain tails diverge");
        if a.is_none() {
            break;
        }
    }
}

#[test]
fn wheel_matches_heap_on_dense_same_time_ties() {
    for seed in 0..8 {
        run_case(0xA11CE + seed, 4_000, "ties");
    }
}

#[test]
fn wheel_matches_heap_on_subtick_time_differences() {
    for seed in 0..8 {
        run_case(0xB0B + seed, 4_000, "subtick");
    }
}

#[test]
fn wheel_matches_heap_with_far_future_outliers() {
    for seed in 0..8 {
        run_case(0xCAFE + seed, 4_000, "outliers");
    }
}

#[test]
fn wheel_matches_heap_with_negative_times() {
    for seed in 0..8 {
        run_case(0xD00D + seed, 2_000, "negative");
    }
}

/// Monotone pop times with FIFO ties is implied by matching the heap,
/// but assert it directly so a bug in the *oracle* cannot hide one in
/// the wheel.
#[test]
fn wheel_pops_are_time_sorted_and_fifo_on_ties() {
    let mut rng = Rng::new(0x5EED);
    let mut wheel = EventQueue::new();
    for tag in 0..5_000usize {
        // 64 distinct times guarantee heavy tie traffic.
        let t = (rng.next_below(64) as f64) * 0.125;
        wheel.push(t, ev(tag));
    }
    let mut last_t = f64::NEG_INFINITY;
    let mut last_tag_at_t: Option<usize> = None;
    while let Some((t, e)) = wheel.pop() {
        assert!(t >= last_t, "pop times went backwards: {t} after {last_t}");
        let tag = match e {
            Event::DeviceInferDone { device, .. }
            | Event::SrWindow { device }
            | Event::DeviceResume { device } => device,
            Event::ServerBatchDone { server } => server,
            _ => unreachable!(),
        };
        if t == last_t {
            // Same time => push order (tags ascend in push order).
            assert!(
                last_tag_at_t.is_some_and(|prev| prev < tag),
                "tie at t={t} broke FIFO: {last_tag_at_t:?} then {tag}"
            );
        }
        last_t = t;
        last_tag_at_t = Some(tag);
    }
}

#[test]
#[should_panic(expected = "non-finite event time")]
fn wheel_push_panics_on_nan() {
    let mut q = EventQueue::new();
    q.push(f64::NAN, Event::SrWindow { device: 0 });
}

#[test]
#[should_panic(expected = "non-finite event time")]
fn wheel_push_panics_on_infinity() {
    let mut q = EventQueue::new();
    q.push(f64::INFINITY, Event::SrWindow { device: 0 });
}

#[test]
#[should_panic(expected = "non-finite event time")]
fn heap_oracle_push_panics_on_nan_too() {
    let mut q = BinaryHeapQueue::new();
    q.push(f64::NAN, Event::SrWindow { device: 0 });
}
