//! Regression tests for the sharded server pool and the engine split.
//!
//! Invariants pinned here:
//! * `--shards 1` (the default, `ShardingKind::Single`) is the
//!   pre-split engine: the mixed-pool admission fixture reproduces its
//!   exact pre-refactor values, and an explicit `--set
//!   server.sharding=1` run is bit-identical to the default path;
//! * per-model sharding on a *homogeneous* pool builds one shard and
//!   is bit-identical to the single shared queue;
//! * admission is shard-local: a request the pool-wide fastest model
//!   could serve is shed when its routed shard's own model cannot make
//!   the deadline (and the same request is admitted unsharded);
//! * replicas steal only when their own shard is drained (pool-level
//!   panics cover the invariant; end-to-end, a mixed sharded pool
//!   steals without losing samples and the trace exposes per-shard
//!   depths + the cumulative steal counter);
//! * the `sharded-pool` preset and the `bench scale` smoke harness run
//!   end-to-end on the synthetic tables.

use multitascpp::config::latency::server_latency_model;
use multitascpp::config::scenario::{Scenario, SchedulerKind, ServerPolicy, ShardingKind};
use multitascpp::config::spec::ScenarioSpec;
use multitascpp::config::SystemConfig;
use multitascpp::data::dataset::Dataset;
use multitascpp::metrics::RunMetrics;
use multitascpp::models::outputs::{OutputProvider, SyntheticOutputs};
use multitascpp::models::registry::test_meta_json;
use multitascpp::models::{Registry, Tier};
use multitascpp::scheduler::{Scheduler, StaticSched};
use multitascpp::sim::event::EventQueue;
use multitascpp::sim::{
    run_scenario, DeviceSpec, ForwardingVerdict, PendingRequest, RequestId, ServerSubsystem,
    SimEngine,
};

// --- harness (same shape as tests/hetero_pool.rs) ---------------------------

fn registry() -> Registry {
    Registry::from_meta(std::path::Path::new("/tmp/test_artifacts"), &test_meta_json()).unwrap()
}

fn dataset() -> Dataset {
    Dataset::synthetic_for_tests(5000, 4, 10)
}

fn provider(n: usize) -> SyntheticOutputs {
    SyntheticOutputs::new(
        n,
        &[
            ("dev_low", 0.72),
            ("dev_mid", 0.75),
            ("dev_high", 0.77),
            ("srv_inception", 0.785),
            ("srv_effnetb3", 0.815),
        ],
        42,
    )
}

fn run(scn: &Scenario) -> RunMetrics {
    let cfg = SystemConfig::default();
    let reg = registry();
    let ds = dataset();
    let mut prov = provider(ds.n).into_cached();
    run_scenario(scn, &cfg, &reg, &ds, &mut prov).unwrap()
}

fn mixed_criticality(n: usize, samples: usize) -> Scenario {
    Scenario::heterogeneous(n, "srv_inception")
        .with_scheduler(SchedulerKind::Static)
        .with_slo(150.0)
        .with_tier_slo(Tier::Low, 100.0)
        .with_tier_slo(Tier::High, 400.0)
        .with_samples(samples)
        .with_seed(0)
}

fn assert_bit_identical(a: &RunMetrics, b: &RunMetrics, what: &str) {
    assert_eq!(a.overall.samples, b.overall.samples, "{what}: samples");
    assert_eq!(a.overall.satisfied, b.overall.satisfied, "{what}: satisfied");
    assert_eq!(a.overall.correct, b.overall.correct, "{what}: correct");
    assert_eq!(a.overall.forwarded, b.overall.forwarded, "{what}: forwarded");
    assert_eq!(a.shed, b.shed, "{what}: shed");
    assert_eq!(a.steals, b.steals, "{what}: steals");
    assert_eq!(
        a.per_server_batches, b.per_server_batches,
        "{what}: per-replica batches"
    );
    assert_eq!(
        a.latencies.values(),
        b.latencies.values(),
        "{what}: latency sequence"
    );
    assert!(
        (a.makespan_s - b.makespan_s).abs() < 1e-12,
        "{what}: makespan {} vs {}",
        a.makespan_s,
        b.makespan_s
    );
}

// --- `--shards 1` is the pre-split engine -----------------------------------

/// Forwards every sample (BvSB 0 < any threshold); device predictions
/// are always correct so accuracy never confounds the assertions.
struct ForwardAll;

impl OutputProvider for ForwardAll {
    fn device_output(&mut self, _model: &str, _sample: usize) -> (f32, bool) {
        (0.0, true)
    }

    fn server_outputs(&mut self, _model: &str, samples: &[usize]) -> Vec<bool> {
        vec![true; samples.len()]
    }
}

fn one_low_device(slo_ms: f64, samples: usize) -> DeviceSpec {
    DeviceSpec {
        tier: Tier::Low,
        stream: (0..samples).collect(),
        arrivals: Vec::new(),
        initial_threshold: 0.5,
        sr_target: 95.0,
        slo_ms,
        offline_at: None,
        offline_duration_s: 0.0,
    }
}

fn run_engine(
    scheduler: &mut dyn Scheduler,
    provider: &mut dyn OutputProvider,
    policy: &ServerPolicy,
    specs: Vec<DeviceSpec>,
) -> RunMetrics {
    let cfg = SystemConfig::default();
    let latency_of = |m: &str| server_latency_model(m);
    SimEngine::new(
        &cfg,
        scheduler,
        Vec::new(),
        provider,
        &latency_of,
        "srv_inception",
        policy,
        specs,
        0,
    )
    .run()
    .unwrap()
}

/// The PR 3 mixed-pool admission fixture, re-pinned through the split
/// engine with explicit single sharding: exact pre-refactor values
/// (nothing shed, every sample in SLO, every batch on the fast
/// replica). A change to the `--shards 1` path breaks this before any
/// sweep does.
#[test]
fn single_sharding_reproduces_pre_split_fixture_values() {
    let policy = ServerPolicy {
        replicas: 2,
        models: vec!["srv_effnetb3".into(), "srv_inception".into()],
        shed: true,
        sharding: ShardingKind::Single,
        ..ServerPolicy::default()
    };
    let mut sched = StaticSched::new();
    let mut prov = ForwardAll;
    let m = run_engine(&mut sched, &mut prov, &policy, vec![one_low_device(55.0, 10)]);
    assert_eq!(m.overall.samples, 10);
    assert_eq!(m.shed, 0, "feasible-on-fast-replica requests were shed");
    assert_eq!(m.overall.satisfied, 10, "served via inception => in-SLO");
    assert_eq!(m.per_server_batches, vec![0, 10]);
    assert_eq!(m.steals, 0, "single shard has nothing to steal");
}

#[test]
fn explicit_single_sharding_is_bit_identical_to_default() {
    // The `--set server.sharding=1` path and the untouched default
    // must take the identical code path on a mixed heterogeneous pool.
    let base = mixed_criticality(12, 300)
        .with_server_models(vec!["srv_effnetb3", "srv_inception"])
        .with_slack_batch(true)
        .with_shed(true);
    let explicit = base.clone().with_sharding(ShardingKind::Single);
    assert_bit_identical(&run(&base), &run(&explicit), "explicit single sharding");
}

#[test]
fn per_model_sharding_on_homogeneous_pool_is_bit_identical_to_single() {
    // One placed model = one shard: the sharded pool must reproduce the
    // shared-queue schedule exactly (routing is trivial, stealing never
    // fires, shard-local admission is pool-wide admission).
    let single = mixed_criticality(12, 300).with_replicas(2);
    let sharded = single.clone().with_sharding(ShardingKind::PerModel);
    let auto = single.clone().with_sharding(ShardingKind::Auto);
    let a = run(&single);
    let b = run(&sharded);
    assert_bit_identical(&a, &b, "homogeneous per-model sharding");
    assert_bit_identical(&a, &run(&auto), "homogeneous auto sharding");
    assert_eq!(b.steals, 0);
    // The trace still reports the (single) shard's depth.
    assert!(b
        .trace
        .iter()
        .all(|p| p.per_shard_depth.len() == 1 && p.per_shard_depth[0] == p.queue_len));
}

// --- shard-local admission ---------------------------------------------------

/// Drives the server subsystem directly through the fleet/server
/// interface: shard-local admission must shed a request whose routed
/// shard cannot make the deadline even though the pool-wide fastest
/// model could — and the identical request is admitted unsharded.
#[test]
fn admission_is_shard_local_on_a_mixed_pool() {
    let cfg = SystemConfig::default();
    let latency_of = |m: &str| server_latency_model(m);
    let policy = ServerPolicy {
        replicas: 2,
        models: vec!["srv_effnetb3".into(), "srv_inception".into()],
        shed: true,
        sharding: ShardingKind::PerModel,
        ..ServerPolicy::default()
    };
    let mut sub = ServerSubsystem::new(&cfg, &policy, "srv_inception", Vec::new(), &latency_of);
    let mut events = EventQueue::new();
    let mut metrics = RunMetrics::default();
    let req = |id: u32, deadline_s: f64| PendingRequest {
        id: RequestId::from_parts(id, 0),
        device: 0,
        tier: Tier::Low,
        start_s: 0.0,
        deadline_s,
        arrival_s: 0.0,
    };
    // Generous deadlines: r0 routes to the faster inception shard and
    // goes straight in flight on replica 1.
    let (v, _) = sub.on_arrival(0.0, req(0, 1.0), &mut events, &mut metrics);
    assert_eq!(v, ForwardingVerdict::Queued);
    assert_eq!(sub.busy_count(), 1);
    // r1 also routes to the inception shard (its replica is busy), and
    // the idle effnet replica — its own shard empty — steals it.
    let (v, _) = sub.on_arrival(0.0, req(1, 1.0), &mut events, &mut metrics);
    assert_eq!(v, ForwardingVerdict::Queued);
    assert_eq!(sub.busy_count(), 2);
    assert_eq!(sub.steal_count(), 1, "idle effnet replica must steal");
    // r2 queues in the inception shard (both replicas busy now).
    let (v, _) = sub.on_arrival(0.0, req(2, 1.0), &mut events, &mut metrics);
    assert_eq!(v, ForwardingVerdict::Queued);
    assert_eq!(sub.shard_depths(), vec![0, 1]);
    // r3: 20 ms of slack. The inception shard's floor (15.03 ms batch-1
    // + 2 ms return hop) fits, but its backlog makes routing pick the
    // effnet shard — whose own floor (25.06 + 2 ms) cannot make the
    // deadline. Shard-local admission sheds it.
    let (v, _) = sub.on_arrival(0.0, req(3, 0.020), &mut events, &mut metrics);
    assert_eq!(v, ForwardingVerdict::Shed);
    assert_eq!(sub.shed_count(), 1);
    // The identical request against an unsharded pool is admitted: the
    // shared queue's floor is the pool-wide fastest (inception).
    let single = ServerPolicy {
        sharding: ShardingKind::Single,
        ..policy.clone()
    };
    let mut sub1 = ServerSubsystem::new(&cfg, &single, "srv_inception", Vec::new(), &latency_of);
    let (v, _) = sub1.on_arrival(0.0, req(3, 0.020), &mut events, &mut metrics);
    assert_eq!(v, ForwardingVerdict::Queued);
}

/// Regression for steal-aware admission (ROADMAP "steal-aware
/// admission: count sibling capacity"): the routed shard's floor used
/// to be its own model's batch-1 latency alone, so a request only a
/// fast *sibling* could serve in time was shed even though that
/// sibling sat idle with a drained shard — one steal away from serving
/// it. The floor now counts idle sibling-shard capacity eligible to
/// steal.
///
/// Numbers: both EfficientNetB3 replicas are busy, so the arrival
/// routes to the effnet shard ((0+1) x 25.06 / 2 = 12.53 beats
/// inception's 15.03). A 20 ms deadline fits InceptionV3's 15.03 ms
/// batch-1 + 2 ms return hop but not EfficientNetB3's 25.06 + 2 ms:
/// the old shard-local floor shed it; with the idle inception replica
/// (own shard empty) counted, it is admitted and immediately stolen.
#[test]
fn steal_aware_admission_counts_idle_sibling_capacity() {
    let cfg = SystemConfig::default();
    let latency_of = |m: &str| server_latency_model(m);
    let policy = ServerPolicy {
        replicas: 3,
        models: vec![
            "srv_effnetb3".into(),
            "srv_effnetb3".into(),
            "srv_inception".into(),
        ],
        shed: true,
        sharding: ShardingKind::PerModel,
        ..ServerPolicy::default()
    };
    let mut sub = ServerSubsystem::new(&cfg, &policy, "srv_inception", Vec::new(), &latency_of);
    let mut events = EventQueue::new();
    let mut metrics = RunMetrics::default();
    let req = |id: u32, deadline_s: f64| PendingRequest {
        id: RequestId::from_parts(id, 0),
        device: 0,
        tier: Tier::Low,
        start_s: 0.0,
        deadline_s,
        arrival_s: 0.0,
    };
    // Two generous arrivals occupy both effnet replicas (the effnet
    // shard scores 12.53 vs inception's 15.03, so both route there).
    for id in 0..2 {
        let (v, _) = sub.on_arrival(0.0, req(id, 1.0), &mut events, &mut metrics);
        assert_eq!(v, ForwardingVerdict::Queued);
    }
    assert_eq!(sub.busy_count(), 2);
    assert_eq!(sub.steal_count(), 0, "own-shard service needs no steal");
    // The tight request also routes to the (busy) effnet shard. Its
    // 20 ms slack fits only the idle inception replica — which is
    // eligible to steal. Admission must count it, not shed.
    let (v, _) = sub.on_arrival(0.0, req(2, 0.020), &mut events, &mut metrics);
    assert_eq!(
        v,
        ForwardingVerdict::Queued,
        "feasible-via-steal request was shed while a sibling sat idle"
    );
    assert_eq!(sub.shed_count(), 0);
    assert_eq!(sub.steal_count(), 1, "the idle inception replica steals it");
    assert_eq!(sub.busy_count(), 3);
    // With every replica busy there is no steal-eligible capacity left:
    // the same tight request now sheds against the routed shard's own
    // floor — the fix widens admission only when a sibling is idle.
    let (v, _) = sub.on_arrival(0.0, req(3, 0.020), &mut events, &mut metrics);
    assert_eq!(v, ForwardingVerdict::Shed);
    assert_eq!(sub.shed_count(), 1);
}

// --- work stealing end-to-end ------------------------------------------------

/// A mixed sharded pool under real load: routing concentrates work on
/// the fast shard, so the slow replica's only path to work is
/// stealing. Samples conserve, steals happen, and the trace exposes
/// consistent per-shard depths plus a monotone cumulative steal count.
#[test]
fn sharded_mixed_pool_steals_without_losing_samples() {
    let scn = mixed_criticality(24, 300)
        .with_server_models(vec!["srv_effnetb3", "srv_inception"])
        .with_sharding(ShardingKind::PerModel);
    let m = run(&scn);
    assert_eq!(m.overall.samples, 24 * 300, "sample conservation");
    assert!(m.steals > 0, "slow replica must steal from the fast shard");
    assert!(
        m.per_server_batches[0] > 0,
        "stolen batches run on the effnet replica: {:?}",
        m.per_server_batches
    );
    assert!(m.overall.satisfaction_rate().is_finite());
    for p in &m.trace {
        assert_eq!(p.per_shard_depth.len(), 2, "one depth per shard");
        assert_eq!(
            p.per_shard_depth.iter().sum::<usize>(),
            p.queue_len,
            "shard depths must sum to the pool depth"
        );
    }
    let steals: Vec<usize> = m.trace.iter().map(|p| p.steals).collect();
    assert!(
        steals.windows(2).all(|w| w[0] <= w[1]),
        "cumulative steal trace must be monotone"
    );
    assert_eq!(*steals.last().unwrap(), m.steals);
}

/// Stealing is an improvement lever, not a regression: on the same
/// workload the sharded pool must stay within noise of — or beat — the
/// shared queue on SLO satisfaction (here: not collapse).
#[test]
fn sharding_does_not_collapse_slo_satisfaction() {
    let base = mixed_criticality(24, 300).with_server_models(vec!["srv_effnetb3", "srv_inception"]);
    let single = run(&base);
    let sharded = run(&base.clone().with_sharding(ShardingKind::PerModel));
    assert_eq!(single.overall.samples, sharded.overall.samples);
    assert!(
        sharded.overall.satisfaction_rate() > single.overall.satisfaction_rate() - 15.0,
        "single {:.2} vs sharded {:.2}",
        single.overall.satisfaction_rate(),
        sharded.overall.satisfaction_rate()
    );
}

// --- surface -----------------------------------------------------------------

#[test]
fn sharded_pool_preset_runs_end_to_end() {
    let mut spec = ScenarioSpec::preset("sharded-pool").unwrap();
    spec.set("samples", "120").unwrap();
    assert_eq!(spec.server.sharding, ShardingKind::PerModel);
    let scn = spec.validate().unwrap();
    assert_eq!(scn.server.replicas, 4);
    let reg = registry();
    let ds = dataset();
    let mut prov = provider(ds.n).into_cached();
    let m = run_scenario(&scn, &SystemConfig::default(), &reg, &ds, &mut prov).unwrap();
    assert_eq!(m.overall.samples, scn.total_devices() * 120);
    assert!(m.overall.satisfaction_rate().is_finite());
    // Two distinct models -> two shards in the trace.
    assert!(m.trace.iter().all(|p| p.per_shard_depth.len() == 2));
}

#[test]
fn bench_scale_smoke_emits_report() {
    let out = std::env::temp_dir().join("mtpp_test_bench_scale.json");
    let _ = std::fs::remove_file(&out);
    let smoke = multitascpp::bench::scale::ScaleOptions {
        smoke: true,
        devices: None,
        fanout: 0,
    };
    let points = multitascpp::bench::scale::run_scale(&smoke, &out).unwrap();
    // 2 device counts x {single, sharded, sharded-par, trace}.
    assert_eq!(points.len(), 8);
    assert!(points.iter().all(|p| p.events > 0 && p.wall_s > 0.0));
    assert!(
        points
            .iter()
            .filter(|p| p.label == "single")
            .all(|p| p.steals == 0),
        "single-queue cells cannot steal"
    );
    // The parallel cells step the SAME workload (digest matches the
    // serial sharded cell — server.parallel is zeroed before hashing)
    // and produce the same deterministic counters.
    let par_cells: Vec<_> = points.iter().filter(|p| p.label == "sharded-par").collect();
    assert_eq!(par_cells.len(), 2);
    for t in &par_cells {
        assert_eq!((t.exec, t.threads), ("parallel", 2));
        let serial = points
            .iter()
            .find(|p| p.label == "sharded" && p.devices == t.devices)
            .expect("matching serial cell");
        assert_eq!((serial.exec, serial.threads), ("serial", 0));
        assert_eq!(serial.scenario_digest, t.scenario_digest);
        assert_eq!(
            (serial.events, serial.shed, serial.steals),
            (t.events, t.shed, t.steals),
            "parallel stepping must be bit-identical at n={}",
            t.devices
        );
    }
    // The replay cells actually replayed: one per device count, and the
    // workload-identity digest differs from the synthetic cells'.
    let trace_cells: Vec<_> = points.iter().filter(|p| p.label == "trace").collect();
    assert_eq!(trace_cells.len(), 2);
    assert!(trace_cells
        .iter()
        .all(|t| points.iter().any(|p| p.label == "sharded"
            && p.devices == t.devices
            && p.scenario_digest != t.scenario_digest)));
    let text = std::fs::read_to_string(&out).unwrap();
    let json = multitascpp::util::json::Json::parse(&text).unwrap();
    assert_eq!(json.get("bench").and_then(|j| j.as_str()), Some("scale"));
    assert_eq!(
        json.get("points").and_then(|j| j.as_arr()).map(|a| a.len()),
        Some(8)
    );
    assert_eq!(
        json.get("runs").and_then(|j| j.as_arr()).map(|a| a.len()),
        Some(1)
    );
    // Append semantics: a second run extends the history instead of
    // overwriting the report; the top level mirrors the latest run.
    // This run fans the cells over 2 workers — the deterministic
    // counters and report shape must not notice.
    let fanned = multitascpp::bench::scale::ScaleOptions {
        smoke: true,
        devices: None,
        fanout: 2,
    };
    let points2 = multitascpp::bench::scale::run_scale(&fanned, &out).unwrap();
    assert_eq!(points2.len(), 8);
    for (a, b) in points.iter().zip(&points2) {
        assert_eq!(
            (a.label, a.devices, &a.scenario_digest, a.events, a.shed),
            (b.label, b.devices, &b.scenario_digest, b.events, b.shed),
            "fanned-out run must merge in grid order with identical cells"
        );
    }
    let text = std::fs::read_to_string(&out).unwrap();
    let json = multitascpp::util::json::Json::parse(&text).unwrap();
    assert_eq!(
        json.get("runs").and_then(|j| j.as_arr()).map(|a| a.len()),
        Some(2)
    );
    assert_eq!(
        json.get("points").and_then(|j| j.as_arr()).map(|a| a.len()),
        Some(8)
    );
}
