"""Calibration logic tests (static-threshold rule, switching limits)."""

import numpy as np

from compile import calibrate as C


def synth_curve(fwd_at, acc_gain):
    """Monotone cascade curve: fwd_frac and acc rise with threshold."""
    rows = []
    for c in C.THRESH_GRID:
        fwd = min(1.0, c * fwd_at)
        rows.append({"thresh": c, "fwd_frac": fwd, "acc": 0.7 + acc_gain * fwd})
    return rows


def test_static_threshold_prefers_30pct_when_cheap():
    # Accuracy saturates fast: the 30%-forwarding threshold costs <1pp.
    rows = []
    for c in C.THRESH_GRID:
        fwd = min(1.0, c)
        acc = 0.70 + 0.08 * min(fwd, 0.25) / 0.25  # flat after 25% fwd
        rows.append({"thresh": c, "fwd_frac": fwd, "acc": acc})
    t = C.static_threshold(rows)
    assert abs(t - 0.30) < 0.05


def test_static_threshold_respects_1pp_rule():
    # Accuracy keeps climbing: 30% fwd loses >1pp, so the rule picks the
    # lowest threshold within 1pp of best.
    rows = synth_curve(fwd_at=1.0, acc_gain=0.10)
    t = C.static_threshold(rows)
    best = max(r["acc"] for r in rows)
    at = min(rows, key=lambda r: abs(r["thresh"] - t))
    assert (best - at["acc"]) * 100.0 <= 1.0 + 1e-9
    # and it is the *lowest* such threshold
    for r in rows:
        if r["thresh"] < t:
            assert (best - r["acc"]) * 100.0 > 1.0


def test_cascade_curve_monotone_forwarding():
    rng = np.random.default_rng(0)
    bvsb = rng.uniform(0, 1, 2000).astype(np.float32)
    dev_c = rng.integers(0, 2, 2000).astype(np.uint8)
    srv_c = np.ones(2000, dtype=np.uint8)
    curve = C.cascade_curve(bvsb, dev_c, srv_c)
    fwd = [r["fwd_frac"] for r in curve]
    assert all(a <= b + 1e-9 for a, b in zip(fwd, fwd[1:]))
    # perfect server => accuracy also monotone in threshold
    acc = [r["acc"] for r in curve]
    assert all(a <= b + 1e-9 for a, b in zip(acc, acc[1:]))


def test_switching_limits_ordered():
    fast = synth_curve(fwd_at=1.0, acc_gain=0.06)
    heavy = synth_curve(fwd_at=1.0, acc_gain=0.09)
    lims = C.switching_limits({"srv_inception": fast, "srv_effnetb3": heavy}, "low")
    assert 0.0 < lims["c_lower"] <= lims["c_upper"] <= 1.0
