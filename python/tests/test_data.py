"""Dataset generator tests: determinism, splits, binary roundtrip."""

import os
import tempfile

import numpy as np

from compile import data as D


def test_prototypes_unit_norm():
    protos = D.make_prototypes()
    assert protos.shape == (D.NUM_CLASSES, D.INPUT_DIM)
    np.testing.assert_allclose(
        np.linalg.norm(protos, axis=1), np.ones(D.NUM_CLASSES), rtol=1e-5
    )


def test_dataset_deterministic():
    a = D.sample_dataset(D.make_prototypes(), 500, seed=3)
    b = D.sample_dataset(D.make_prototypes(), 500, seed=3)
    np.testing.assert_array_equal(a.x, b.x)
    np.testing.assert_array_equal(a.y, b.y)


def test_different_seeds_differ():
    a = D.sample_dataset(D.make_prototypes(), 500, seed=3)
    b = D.sample_dataset(D.make_prototypes(), 500, seed=4)
    assert not np.array_equal(a.x, b.x)


def test_splits_are_paper_shaped():
    ds = D.sample_dataset(D.make_prototypes(), D.N_EVAL, seed=13)
    cal = D.calibration_slice(ds)
    pool = D.eval_pool_slice(ds)
    assert cal.n == 10_000 and pool.n == 40_000
    np.testing.assert_array_equal(cal.x, ds.x[:10_000])
    np.testing.assert_array_equal(pool.y, ds.y[10_000:])


def test_binary_roundtrip():
    ds = D.sample_dataset(D.make_prototypes(), 300, seed=5)
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "ds.bin")
        D.write_dataset(path, ds)
        # header 20B + x + y + sigma
        expected = 20 + 4 * 300 * D.INPUT_DIM + 4 * 300 + 4 * 300
        assert os.path.getsize(path) == expected
        back = D.read_dataset(path)
    np.testing.assert_array_equal(ds.x.astype("<f4"), back.x)
    np.testing.assert_array_equal(ds.y, back.y)
    np.testing.assert_array_equal(ds.sigma.astype("<f4"), back.sigma)


def test_difficulty_correlates_with_error():
    """Harder (larger sigma) samples must be harder for the Bayes-ish
    nearest-prototype rule — the property the cascade architecture
    relies on."""
    protos = D.make_prototypes()
    ds = D.sample_dataset(protos, 4000, seed=9)
    pred = (ds.x @ protos.T).argmax(axis=1)
    correct = pred == ds.y
    assert ds.sigma[~correct].mean() > ds.sigma[correct].mean() * 1.2
