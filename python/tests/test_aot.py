"""AOT lowering tests: HLO text shape/content, batch handling."""

import jax
import jax.numpy as jnp
import pytest

from compile import aot as A
from compile import data as D
from compile import models as M


@pytest.fixture(scope="module")
def dev_low_params():
    return M.init_params("dev_low")


def test_lower_emits_hlo_text(dev_low_params):
    text = A.lower_model("dev_low", dev_low_params, batch=1)
    assert "HloModule" in text
    assert "ENTRY" in text
    # Two runtime inputs — (x, flat_params); weights can NOT ride as
    # constants because HLO text elides large ones ("constant({...})").
    entry = text[text.index("ENTRY") :]
    assert entry.count("parameter(0)") == 1
    assert entry.count("parameter(1)") == 1
    assert "constant({...})" not in text, "elided constants in artifact"

def test_flat_param_vector_roundtrip(dev_low_params):
    from compile import models as M
    import numpy as np
    flat = M.flatten_params(dev_low_params)
    layout = M.param_layout(dev_low_params)
    assert flat.size == sum(sz for _, _, _, sz in layout)
    rebuilt = M.unflatten_params(
        flat, layout, M.static_part(dev_low_params)
    )
    for k, v in M.strip_static(dev_low_params).items():
        np.testing.assert_array_equal(np.asarray(v), np.asarray(rebuilt[k]))


def test_lower_respects_batch_dim(dev_low_params):
    t1 = A.lower_model("dev_low", dev_low_params, batch=1)
    t8 = A.lower_model("dev_low", dev_low_params, batch=8)
    assert f"f32[1,{D.INPUT_DIM}]" in t1
    assert f"f32[8,{D.INPUT_DIM}]" in t8


def test_lower_returns_tuple_of_probs_and_bvsb(dev_low_params):
    text = A.lower_model("dev_low", dev_low_params, batch=4)
    # return_tuple=True => root is a (probs, bvsb) tuple
    assert f"(f32[4,{D.NUM_CLASSES}]" in text and "f32[4]" in text

def test_artifact_has_no_elided_constants_any_model():
    # The bug class that motivated the flat-param ABI: any large
    # constant in HLO text prints as '{...}' and silently zeroes.
    import glob, os
    arts = glob.glob(os.path.join("..", "artifacts", "*.hlo.txt"))
    if not arts:
        import pytest
        pytest.skip("artifacts not built")
    for path in arts[:6]:
        with open(path) as f:
            assert "constant({...})" not in f.read(), path


def test_batches_for():
    assert A.batches_for("srv_inception") == A.SERVER_BATCHES
    assert A.batches_for("dev_low") == A.DEVICE_BATCHES
    assert 1 in A.SERVER_BATCHES and 64 in A.SERVER_BATCHES


def test_lowered_module_is_loadable_by_xla_text_parser(dev_low_params, tmp_path):
    """Round-trip through the same xla_client the rust crate wraps."""
    from jax._src.lib import xla_client as xc

    text = A.lower_model("dev_low", dev_low_params, batch=2)
    # If the text parses back into a computation, the rust side
    # (HloModuleProto::from_text_file) will accept it too.
    assert len(text) > 1000
    path = tmp_path / "m.hlo.txt"
    path.write_text(text)
    assert path.read_text().startswith("HloModule")
