"""L2 model-zoo tests: kernel/ref forward equivalence, shapes, io."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import data as D
from compile import models as M

TOL = dict(rtol=5e-5, atol=5e-5)


@pytest.fixture(scope="module")
def batch_x():
    return jax.random.normal(jax.random.PRNGKey(0), (6, D.INPUT_DIM), jnp.float32)


@pytest.mark.parametrize("name", list(M.MODEL_SPECS))
def test_forward_shapes(name, batch_x):
    params = M.init_params(name)
    probs, bvsb = M.forward(name, params, batch_x, impl=M.RefImpl)
    assert probs.shape == (6, D.NUM_CLASSES)
    assert bvsb.shape == (6,)
    np.testing.assert_allclose(jnp.sum(probs, axis=-1), np.ones(6), rtol=1e-5)


@pytest.mark.parametrize("name", list(M.MODEL_SPECS))
def test_kernel_impl_matches_ref_impl(name, batch_x):
    """The AOT-lowered graph (Pallas kernels) must agree with the
    training-path graph (pure jnp) — this is what makes calibration on
    the ref path valid for artifacts built on the kernel path."""
    params = M.init_params(name)
    pk, bk = M.forward(name, params, batch_x, impl=M.KernelImpl)
    pr, br = M.forward(name, params, batch_x, impl=M.RefImpl)
    np.testing.assert_allclose(pk, pr, **TOL)
    np.testing.assert_allclose(bk, br, **TOL)


@pytest.mark.parametrize("name", ["dev_low", "srv_deit"])
def test_params_save_load_roundtrip(name):
    params = M.init_params(name)
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, f"{name}.npz")
        M.save_params(path, params)
        loaded = M.load_params(path)
    assert set(loaded) == set(params)
    for k, v in params.items():
        if k.startswith("_"):
            assert loaded[k] == v
        else:
            np.testing.assert_array_equal(np.asarray(v), np.asarray(loaded[k]))


def test_device_models_have_lossy_projection():
    for name in M.DEVICE_MODELS:
        spec = M.MODEL_SPECS[name]
        assert spec.proj_dim is not None and spec.proj_dim < D.INPUT_DIM


def test_batch_size_one_works():
    x = jax.random.normal(jax.random.PRNGKey(1), (1, D.INPUT_DIM), jnp.float32)
    for name in ("dev_low", "srv_deit"):
        probs, bvsb = M.forward(name, M.init_params(name), x, impl=M.RefImpl)
        assert probs.shape == (1, D.NUM_CLASSES) and bvsb.shape == (1,)


def test_forward_deterministic(batch_x):
    params = M.init_params("dev_mid")
    a = M.forward("dev_mid", params, batch_x, impl=M.RefImpl)
    b = M.forward("dev_mid", params, batch_x, impl=M.RefImpl)
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
