"""Training-loop tests (hand-rolled Adam)."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import data as D
from compile import models as M
from compile import train as T


def test_adam_reduces_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    opt = T.adam_init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(300):
        grads = jax.grad(loss)(params)
        params, opt = T.adam_update(params, grads, opt, lr=0.05)
    assert float(loss(params)) < 1e-3


def test_cross_entropy_matches_manual():
    logits = jnp.array([[2.0, 0.0, -1.0]])
    labels = jnp.array([0])
    want = -jax.nn.log_softmax(logits)[0, 0]
    np.testing.assert_allclose(T.cross_entropy(logits, labels), want, rtol=1e-6)


def test_short_training_beats_chance():
    protos = D.make_prototypes()
    train = D.sample_dataset(protos, 3000, seed=21)
    probe = D.sample_dataset(protos, 1000, seed=22)
    old_epochs = dict(T.TRAIN_EPOCHS)
    T.TRAIN_EPOCHS["dev_low"] = 4
    try:
        params = T.train_model("dev_low", train, log=lambda s: None)
    finally:
        T.TRAIN_EPOCHS.update(old_epochs)
    acc = T.accuracy("dev_low", params, probe)
    # 3x chance on the (hard) synthetic task after a 4-epoch snippet.
    assert acc > 3.0 / D.NUM_CLASSES, f"acc {acc} barely above chance"


def test_frozen_projection_not_trained():
    protos = D.make_prototypes()
    train = D.sample_dataset(protos, 1000, seed=23)
    init = M.init_params("dev_low", seed=0)
    old_epochs = dict(T.TRAIN_EPOCHS)
    T.TRAIN_EPOCHS["dev_low"] = 1
    try:
        trained = T.train_model("dev_low", train, seed=0, log=lambda s: None)
    finally:
        T.TRAIN_EPOCHS.update(old_epochs)
    np.testing.assert_array_equal(np.asarray(init["proj"]), np.asarray(trained["proj"]))
    assert not np.array_equal(np.asarray(init["w0"]), np.asarray(trained["w0"]))
