"""L1 kernel correctness: Pallas (interpret) vs pure-jnp oracle.

This is the CORE correctness signal for the compute layer: every kernel
must match ref.py to float32 tolerance on representative and adversarial
shapes, plus hypothesis-driven random sweeps.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention, fused_linear, softmax_bvsb
from compile.kernels import ref

TOL = dict(rtol=2e-5, atol=2e-5)


def rand(key, *shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32) * scale


# ---------------------------------------------------------------- linear


@pytest.mark.parametrize("m,k,n", [(1, 128, 100), (7, 64, 13), (64, 128, 100),
                                   (65, 32, 129), (128, 448, 448), (3, 1, 1)])
@pytest.mark.parametrize("relu", [True, False])
def test_fused_linear_matches_ref(m, k, n, relu):
    x, w, b = rand(0, m, k), rand(1, k, n), rand(2, n)
    got = fused_linear(x, w, b, relu=relu)
    want = ref.linear_ref(x, w, b, relu)
    np.testing.assert_allclose(got, want, **TOL)


def test_fused_linear_block_sizes_equivalent():
    """Tiling must not change the numerics."""
    x, w, b = rand(3, 50, 96, scale=2.0), rand(4, 96, 70), rand(5, 70)
    base = fused_linear(x, w, b, bm=64, bn=128)
    for bm, bn in [(8, 16), (16, 128), (50, 70), (64, 64)]:
        np.testing.assert_allclose(fused_linear(x, w, b, bm=bm, bn=bn), base, **TOL)


def test_fused_linear_relu_clamps_negative():
    x = jnp.array([[1.0, -1.0]], jnp.float32)
    w = jnp.eye(2, dtype=jnp.float32)
    b = jnp.zeros((2,), jnp.float32)
    out = fused_linear(x, w, b, relu=True)
    assert float(out[0, 1]) == 0.0 and float(out[0, 0]) == 1.0


def test_fused_linear_shape_mismatch_raises():
    with pytest.raises(AssertionError):
        fused_linear(rand(0, 4, 8), rand(1, 9, 3), rand(2, 3))


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 96),
    k=st.integers(1, 160),
    n=st.integers(1, 160),
    relu=st.booleans(),
    scale=st.floats(0.01, 8.0),
)
def test_fused_linear_hypothesis(m, k, n, relu, scale):
    x, w, b = rand(10, m, k, scale=scale), rand(11, k, n), rand(12, n)
    # Looser than TOL: with large input scales the tiled kernel's f32
    # accumulation order legitimately differs from jnp.dot by ~1e-4 rel.
    np.testing.assert_allclose(
        fused_linear(x, w, b, relu=relu),
        ref.linear_ref(x, w, b, relu),
        rtol=1e-3,
        atol=1e-4 * max(1.0, scale),
    )


# ---------------------------------------------------------- softmax+bvsb


@pytest.mark.parametrize("m,k", [(1, 100), (64, 100), (65, 100), (7, 2), (128, 1000)])
def test_softmax_bvsb_matches_ref(m, k):
    logits = rand(20, m, k, scale=3.0)
    p, b = softmax_bvsb(logits)
    pr, br = ref.softmax_bvsb_ref(logits)
    np.testing.assert_allclose(p, pr, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(b, br, rtol=1e-5, atol=1e-6)


def test_softmax_bvsb_probabilities_sum_to_one():
    p, _ = softmax_bvsb(rand(21, 33, 100, scale=5.0))
    np.testing.assert_allclose(jnp.sum(p, axis=-1), np.ones(33), rtol=1e-5)


def test_softmax_bvsb_margin_in_unit_interval():
    _, b = softmax_bvsb(rand(22, 50, 100, scale=4.0))
    assert float(jnp.min(b)) >= 0.0 and float(jnp.max(b)) <= 1.0


def test_softmax_bvsb_numerical_stability_large_logits():
    logits = rand(23, 8, 100) * 1e4
    p, b = softmax_bvsb(logits)
    assert bool(jnp.all(jnp.isfinite(p))) and bool(jnp.all(jnp.isfinite(b)))


def test_softmax_bvsb_exact_tie_gives_zero_margin():
    logits = jnp.zeros((4, 10), jnp.float32)
    _, b = softmax_bvsb(logits)
    np.testing.assert_allclose(b, np.zeros(4), atol=1e-7)


def test_softmax_bvsb_confident_sample_has_large_margin():
    logits = jnp.zeros((1, 10), jnp.float32).at[0, 3].set(20.0)
    _, b = softmax_bvsb(logits)
    assert float(b[0]) > 0.99


@settings(max_examples=25, deadline=None)
@given(m=st.integers(1, 80), k=st.integers(2, 300), scale=st.floats(0.1, 30.0))
def test_softmax_bvsb_hypothesis(m, k, scale):
    logits = rand(24, m, k, scale=scale)
    p, b = softmax_bvsb(logits)
    pr, br = ref.softmax_bvsb_ref(logits)
    np.testing.assert_allclose(p, pr, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(b, br, rtol=1e-5, atol=1e-6)


# -------------------------------------------------------------- attention


@pytest.mark.parametrize("b,h,s,dh", [(1, 1, 8, 16), (2, 4, 8, 24), (64, 4, 8, 24),
                                      (3, 2, 5, 7)])
def test_attention_matches_ref(b, h, s, dh):
    q, k, v = rand(30, b, h, s, dh), rand(31, b, h, s, dh), rand(32, b, h, s, dh)
    np.testing.assert_allclose(attention(q, k, v), ref.attention_ref(q, k, v), **TOL)


def test_attention_uniform_when_keys_identical():
    """If all keys are equal, attention output = mean of values."""
    q = rand(33, 1, 1, 4, 8)
    k = jnp.broadcast_to(rand(34, 1, 1, 1, 8), (1, 1, 4, 8))
    v = rand(35, 1, 1, 4, 8)
    out = attention(q, k, v)
    np.testing.assert_allclose(
        out, jnp.broadcast_to(jnp.mean(v, axis=2, keepdims=True), v.shape), **TOL
    )


@settings(max_examples=15, deadline=None)
@given(b=st.integers(1, 8), h=st.integers(1, 4), s=st.integers(1, 16), dh=st.integers(1, 32))
def test_attention_hypothesis(b, h, s, dh):
    q, k, v = rand(36, b, h, s, dh), rand(37, b, h, s, dh), rand(38, b, h, s, dh)
    np.testing.assert_allclose(attention(q, k, v), ref.attention_ref(q, k, v), **TOL)
