"""Build-time training of the model zoo (hand-rolled Adam; optax is not
available in this environment).

Training happens ONCE per build (`make artifacts`), on the synthetic
20k train split, and the resulting parameters are cached under
artifacts/params/. The paper's models are "pretrained on ImageNet's
training set"; this is the equivalent step for our substitutes.
"""

from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data as D
from . import models as M

# Per-model epoch budget: part of the accuracy-ladder calibration.
# Weaker "architectures" also train shorter, like their real
# counterparts trade accuracy for efficiency.
TRAIN_EPOCHS = {
    "dev_low": 10,
    "dev_mid": 12,
    "dev_high": 16,
    "dev_vit": 24,
    "srv_inception": 5,
    "srv_effnetb3": 30,
    "srv_deit": 40,
}
TRAIN_LR = {
    "dev_low": 3e-3,
    "dev_mid": 3e-3,
    "dev_high": 3e-3,
    "dev_vit": 1.5e-3,
    "srv_inception": 3e-3,
    "srv_effnetb3": 2e-3,
    "srv_deit": 1.5e-3,
}
BATCH = 256
LR = 3e-3


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def adam_init(params: dict) -> dict:
    return {
        "m": jax.tree.map(jnp.zeros_like, params),
        "v": jax.tree.map(jnp.zeros_like, params),
        "t": jnp.zeros((), jnp.int32),
    }


def adam_update(params, grads, state, lr=LR, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    tf = t.astype(jnp.float32)
    scale = lr * jnp.sqrt(1 - b2**tf) / (1 - b1**tf)
    new_params = jax.tree.map(
        lambda p, m_, v_: p - scale * m_ / (jnp.sqrt(v_) + eps), params, m, v
    )
    return new_params, {"m": m, "v": v, "t": t}


def train_model(name: str, train: D.Dataset, seed: int = 0, log=print) -> dict:
    """Train one model; returns the full params dict (incl. statics)."""
    params_full = M.init_params(name, seed)
    statics = M.static_part(params_full)
    params = M.strip_static(params_full)
    frozen = {}
    # Lossy projections are frozen: remove from the trainable set.
    for key in ("proj", "tok_proj"):
        if key in params:
            frozen[key] = params.pop(key)
    logits_fn = M.logits_fn(name, impl=M.RefImpl)

    def loss_fn(p, x, y):
        logits = logits_fn({**p, **frozen, **statics}, x)
        return cross_entropy(logits, y)

    @jax.jit
    def step(p, opt, x, y, lr):
        loss, grads = jax.value_and_grad(loss_fn)(p, x, y)
        p, opt = adam_update(p, grads, opt, lr=lr)
        return p, opt, loss

    opt = adam_init(params)
    n = train.n
    rng = np.random.default_rng(seed + 100)
    epochs = TRAIN_EPOCHS[name]
    base_lr = TRAIN_LR[name]
    steps_per_epoch = (n - BATCH + 1 + BATCH - 1) // BATCH
    total_steps = max(1, epochs * steps_per_epoch)
    t0 = time.time()
    global_step = 0
    for epoch in range(epochs):
        order = rng.permutation(n)
        losses = []
        for i in range(0, n - BATCH + 1, BATCH):
            idx = order[i : i + BATCH]
            # Cosine learning-rate decay over the whole schedule.
            lr = base_lr * 0.5 * (1.0 + np.cos(np.pi * global_step / total_steps))
            params, opt, loss = step(params, opt, train.x[idx], train.y[idx], lr)
            losses.append(float(loss))
            global_step += 1
        log(
            f"  [{name}] epoch {epoch + 1}/{epochs} "
            f"loss={np.mean(losses):.4f} ({time.time() - t0:.1f}s)"
        )
    return {**params, **frozen, **statics}


def accuracy(name: str, params: dict, ds: D.Dataset, batch: int = 2048) -> float:
    logits_fn = M.logits_fn(name, impl=M.RefImpl)
    fwd = jax.jit(lambda x: jnp.argmax(logits_fn(params, x), axis=-1))
    correct = 0
    for i in range(0, ds.n, batch):
        pred = fwd(ds.x[i : i + batch])
        correct += int(jnp.sum(pred == ds.y[i : i + batch]))
    return correct / ds.n


def train_all(out_dir: str, log=print) -> dict[str, dict]:
    """Train (or load cached) params for every model in the zoo."""
    os.makedirs(out_dir, exist_ok=True)
    train = D.make_train_set()
    zoo = {}
    for name in M.MODEL_SPECS:
        path = os.path.join(out_dir, f"{name}.npz")
        if os.path.exists(path):
            log(f"  [{name}] cached params: {path}")
            zoo[name] = M.load_params(path)
            continue
        log(f"  [{name}] training...")
        params = train_model(name, train)
        M.save_params(path, params)
        zoo[name] = params
    return zoo
