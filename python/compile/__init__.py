"""Build-time compile path: L2 JAX models + L1 Pallas kernels + AOT
lowering to HLO text. Runs once via `make artifacts`; never imported on
the serving request path (that is all rust + PJRT)."""
