"""Offline calibration (paper §V-A "Baselines" + §IV-E).

Runs on the FIRST 10 000 samples of the eval set (the paper's
calibration split of ImageNet-val) and produces, for every
(device-model, server-model) cascade pair:

* the **Static baseline threshold**: tuned so ~30 % of samples are
  forwarded, unless that costs > 1 pp of cascade accuracy vs. the best
  achievable, in which case the lowest threshold within 1 pp is used —
  verbatim the paper's tuning rule;
* the **model-switching limits** `c_lower` / `c_upper^k` (§IV-E): set
  from the calibration sweep as the thresholds at which the lighter
  server model's cascade stops/starts being within a small accuracy gap
  of the heavier one's;
* measured model accuracies for Table I.

Everything is written to artifacts/meta.json, the contract with
rust/src/models/registry.rs.
"""

from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np

from . import data as D
from . import models as M

# Candidate thresholds swept during calibration (BvSB is in [0, 1]).
THRESH_GRID = np.round(np.arange(0.02, 1.0, 0.02), 4).tolist()
TARGET_FWD = 0.30  # paper: ~30% forwarded
MAX_ACC_LOSS_PP = 1.0  # paper: within 1pp of best cascade accuracy


def model_outputs(name: str, params: dict, ds: D.Dataset, batch: int = 2048):
    """(top1, bvsb, correct) over a dataset via the ref impl (fast path;
    numerics match the kernels to ~1e-6 — asserted in tests)."""
    fwd = jax.jit(
        lambda x: M.forward(name, params, x, impl=M.RefImpl), backend="cpu"
    )
    top1 = np.zeros(ds.n, dtype=np.int32)
    bvsb = np.zeros(ds.n, dtype=np.float32)
    for i in range(0, ds.n, batch):
        probs, margin = fwd(ds.x[i : i + batch])
        top1[i : i + probs.shape[0]] = np.argmax(np.asarray(probs), axis=1)
        bvsb[i : i + probs.shape[0]] = np.asarray(margin)
    correct = (top1 == ds.y).astype(np.uint8)
    return top1, bvsb, correct


def cascade_curve(dev_bvsb, dev_correct, srv_correct):
    """For every candidate threshold: (forward fraction, cascade acc)."""
    rows = []
    for c in THRESH_GRID:
        fwd_mask = dev_bvsb < c
        acc = np.where(fwd_mask, srv_correct, dev_correct).mean()
        rows.append({"thresh": c, "fwd_frac": float(fwd_mask.mean()), "acc": float(acc)})
    return rows


def static_threshold(curve) -> float:
    """The paper's Static tuning rule."""
    best_acc = max(r["acc"] for r in curve)
    # threshold closest to 30% forwarding
    by_fwd = min(curve, key=lambda r: abs(r["fwd_frac"] - TARGET_FWD))
    if (best_acc - by_fwd["acc"]) * 100.0 <= MAX_ACC_LOSS_PP:
        return by_fwd["thresh"]
    # lowest threshold within 1pp of the best cascade accuracy
    for r in curve:  # ascending thresholds
        if (best_acc - r["acc"]) * 100.0 <= MAX_ACC_LOSS_PP:
            return r["thresh"]
    return curve[-1]["thresh"]


def switching_limits(curves_by_server: dict[str, list], tier: str) -> dict:
    """c_lower / c_upper^k for §IV-E ("set after a thorough examination
    of cascade results on a training set").

    * `c_upper`: the threshold at which the *faster* model's cascade is
      already within 0.3 pp of its best achievable accuracy — beyond it
      the fast model has nothing left to give, so if every device sits
      above `c_upper` the system has slack and only a heavier model can
      add accuracy (switch up).
    * `c_lower`: the threshold below which the fast and heavy cascades
      are indistinguishable (<0.15 pp) — if a whole tier has been pushed
      under it the heavy model is pure latency cost (switch down).

    Conservative by construction: `c_upper` sits high on the curve, so
    the controller only switches up when thresholds are pinned near the
    top (ample SLO headroom) and flapping is avoided.
    """
    fast = curves_by_server["srv_inception"]
    heavy = curves_by_server["srv_effnetb3"]
    # c_lower: largest threshold where heavy's edge is still <0.4 pp.
    c_lower = 0.1
    for rf, rh in zip(fast, heavy):
        if (rh["acc"] - rf["acc"]) * 100.0 < 0.4:
            c_lower = rf["thresh"]
        else:
            break
    # c_upper: fast model within 0.05 pp of its own best — only a
    # heavier model can add accuracy beyond this point.
    best_fast = max(r["acc"] for r in fast)
    c_upper = 0.95
    for rf in fast:
        if (best_fast - rf["acc"]) * 100.0 <= 0.05:
            c_upper = rf["thresh"]
            break
    c_upper = max(c_upper, c_lower + 0.05)
    return {"c_lower": c_lower, "c_upper": c_upper}


def calibrate(zoo: dict[str, dict], log=print) -> dict:
    ev = D.make_eval_set()
    cal = D.calibration_slice(ev)
    full_eval = D.eval_pool_slice(ev)

    outputs_cal = {}
    accuracies = {}
    for name, params in zoo.items():
        top1, bvsb, correct = model_outputs(name, params, cal)
        outputs_cal[name] = (top1, bvsb, correct)
        acc_pool = model_outputs(name, params, full_eval)[2].mean()
        accuracies[name] = {
            "calibration": float(correct.mean()),
            "eval_pool": float(acc_pool),
        }
        log(
            f"  [{name}] acc cal={correct.mean() * 100:.2f}% "
            f"pool={acc_pool * 100:.2f}%"
        )

    pairs = {}
    curves_by_dev: dict[str, dict[str, list]] = {}
    for dev in M.DEVICE_MODELS:
        _, dev_bvsb, dev_correct = outputs_cal[dev]
        curves_by_dev[dev] = {}
        for srv in M.SERVER_MODELS:
            srv_correct = outputs_cal[srv][2]
            curve = cascade_curve(dev_bvsb, dev_correct, srv_correct)
            curves_by_dev[dev][srv] = curve
            thresh = static_threshold(curve)
            at = min(curve, key=lambda r: abs(r["thresh"] - thresh))
            pairs[f"{dev}:{srv}"] = {
                "static_threshold": thresh,
                "fwd_frac_at_static": at["fwd_frac"],
                "cascade_acc_at_static": at["acc"],
                "best_cascade_acc": max(r["acc"] for r in curve),
                "curve": curve,
            }
            log(
                f"  [{dev} -> {srv}] static c={thresh:.2f} "
                f"fwd={at['fwd_frac'] * 100:.1f}% acc={at['acc'] * 100:.2f}%"
            )

    switching = {
        tier: switching_limits(curves_by_dev[dev], tier)
        for tier, dev in (("low", "dev_low"), ("mid", "dev_mid"), ("high", "dev_high"))
    }

    return {
        "dataset": {
            "n_eval": D.N_EVAL,
            "n_calibration": D.N_CALIBRATION,
            "input_dim": D.INPUT_DIM,
            "num_classes": D.NUM_CLASSES,
            "noise_log_mean": D.NOISE_LOG_MEAN,
            "noise_log_std": D.NOISE_LOG_STD,
        },
        "models": accuracies,
        "pairs": pairs,
        "switching": switching,
    }


def write_meta(path: str, meta: dict) -> None:
    with open(path, "w") as f:
        json.dump(meta, f, indent=1)
