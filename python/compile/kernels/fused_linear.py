"""Pallas fused linear kernel: y = relu(x @ w + b).

TPU-shaped design (see DESIGN.md §7): the grid tiles the output (M, N)
into VMEM-resident blocks; the full K (contraction) dimension of each
operand block is kept in VMEM — model widths here are <= 512 floats so a
(bm, K) x (K, bn) pair fits comfortably in the ~16 MB VMEM budget. The
matmul inside a block targets the MXU (f32 accumulate); bias-add and ReLU
are fused into the same VMEM pass, so the activations make exactly one
HBM round trip.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and interpret mode lowers to plain HLO that the rust
runtime can run (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Block sizes: multiples of the TPU (8, 128) f32 tile. For the small
# serving models these often exceed (M, N) and clamp to a single block.
DEFAULT_BM = 64
DEFAULT_BN = 128


def _linear_kernel(x_ref, w_ref, b_ref, o_ref, *, relu: bool):
    """One (bm, bn) output block: MXU matmul + fused bias/ReLU epilogue."""
    acc = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    acc = acc + b_ref[...][None, :]
    if relu:
        acc = jnp.maximum(acc, 0.0)
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("relu", "bm", "bn"))
def fused_linear(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    relu: bool = True,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
) -> jax.Array:
    """y = x @ w + b (+ReLU). x: (M, K), w: (K, N), b: (N,) -> (M, N)."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch: {k} vs {k2}"
    assert b.shape == (n,), f"bias shape {b.shape} != ({n},)"
    bm = min(bm, m)
    bn = min(bn, n)
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn))
    return pl.pallas_call(
        functools.partial(_linear_kernel, relu=relu),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),  # x row-panel
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),  # w col-panel
            pl.BlockSpec((bn,), lambda i, j: (j,)),  # bias slice
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w, b)
