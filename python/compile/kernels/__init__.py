"""L1 Pallas kernels (interpret=True) + pure-jnp reference oracles."""

from .attention import attention
from .fused_linear import fused_linear
from .softmax_bvsb import softmax_bvsb

__all__ = ["attention", "fused_linear", "softmax_bvsb"]
