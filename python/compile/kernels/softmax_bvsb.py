"""Pallas fused softmax + Best-versus-Second-Best (BvSB) kernel.

The forwarding decision function of the paper (Eq. 2/3) needs, per
sample, the softmax probabilities *and* the margin P1 - P2 between the
two most probable classes. Computed naively that is three passes over the
logits (max for stability, exp-sum, top-2 over probs). This kernel fuses
all of it into one VMEM-resident pass per row-block: a single HBM read of
the logits produces both outputs, which is exactly the kind of
reduction-epilogue fusion the TPU VPU is good at.

Grid: 1-D over row blocks; the full class dimension (K <= a few thousand
f32) lives in VMEM. Top-2 is computed without sorting: max, then max of
the row with the argmax lane masked out.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BM = 64


def _softmax_bvsb_kernel(logits_ref, probs_ref, bvsb_ref):
    logits = logits_ref[...]  # (bm, K) in VMEM
    # Numerically-stable softmax.
    row_max = jnp.max(logits, axis=-1, keepdims=True)
    unnorm = jnp.exp(logits - row_max)
    denom = jnp.sum(unnorm, axis=-1, keepdims=True)
    probs = unnorm / denom
    probs_ref[...] = probs
    # Top-2 margin without a sort: P1 = max, P2 = max with P1's lane
    # knocked out (mask by equality against the row max of the probs).
    p1 = jnp.max(probs, axis=-1)
    k = probs.shape[-1]
    cols = jax.lax.broadcasted_iota(jnp.int32, probs.shape, 1)
    arg1 = jnp.argmax(probs, axis=-1)
    masked = jnp.where(cols == arg1[:, None], -jnp.inf, probs)
    p2 = jnp.max(masked, axis=-1)
    bvsb_ref[...] = p1 - p2


@functools.partial(jax.jit, static_argnames=("bm",))
def softmax_bvsb(logits: jax.Array, bm: int = DEFAULT_BM):
    """logits: (M, K) -> (probs (M, K), bvsb (M,))."""
    m, k = logits.shape
    bm = min(bm, m)
    grid = (pl.cdiv(m, bm),)
    return pl.pallas_call(
        _softmax_bvsb_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bm, k), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
            pl.BlockSpec((bm,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, k), jnp.float32),
            jax.ShapeDtypeStruct((m,), jnp.float32),
        ],
        interpret=True,
    )(logits)
