"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness).

Every Pallas kernel in this package has an exact reference here; pytest
(and hypothesis sweeps) assert allclose between the two. These refs are
also what the kernels lower to semantically — keep them dependency-free
and obviously correct.
"""

import jax
import jax.numpy as jnp


def linear_ref(x: jax.Array, w: jax.Array, b: jax.Array, relu: bool) -> jax.Array:
    """y = x @ w + b, optionally ReLU'd. x: (M, K), w: (K, N), b: (N,)."""
    y = jnp.dot(x, w, preferred_element_type=jnp.float32) + b[None, :]
    if relu:
        y = jnp.maximum(y, 0.0)
    return y


def softmax_bvsb_ref(logits: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Fused softmax + Best-versus-Second-Best margin (paper Eq. 2).

    logits: (M, K). Returns (probs (M, K), bvsb (M,)) where
    bvsb = P1 - P2, the gap between the two largest softmax entries.
    """
    probs = jax.nn.softmax(logits, axis=-1)
    top2 = jax.lax.top_k(probs, 2)[0]
    return probs, top2[:, 0] - top2[:, 1]


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Scaled dot-product attention. q,k,v: (B, H, S, Dh)."""
    scale = 1.0 / jnp.sqrt(q.shape[-1]).astype(jnp.float32)
    scores = jnp.einsum("bhsd,bhtd->bhst", q, k) * scale
    weights = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", weights, v)
