"""Pallas fused scaled-dot-product attention kernel.

Used by the ViT-style models (the paper's MobileViT / DeiT substitutes).
The GPU-era formulation (one threadblock per (batch, head), shared-memory
tiles of Q/K/V) is re-thought for the TPU model per DESIGN.md §7: the
grid is (B, H) and each program holds its full (S, Dh) Q/K/V slices in
VMEM — sequence lengths here are tiny (S = 8 tokens), so the whole
attention computation for one head is a single VMEM-resident fusion:
QK^T on the MXU, stable softmax on the VPU, and the weighted sum of V on
the MXU again, with no intermediate HBM traffic.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attention_kernel(q_ref, k_ref, v_ref, o_ref, *, scale: float):
    q = q_ref[0, 0]  # (S, Dh)
    k = k_ref[0, 0]
    v = v_ref[0, 0]
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    row_max = jnp.max(scores, axis=-1, keepdims=True)
    weights = jnp.exp(scores - row_max)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    o_ref[0, 0] = jnp.dot(weights, v, preferred_element_type=jnp.float32)


@jax.jit
def attention(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Fused SDPA. q,k,v: (B, H, S, Dh) -> (B, H, S, Dh)."""
    b, h, s, dh = q.shape
    assert k.shape == (b, h, s, dh) and v.shape == (b, h, s, dh)
    scale = 1.0 / float(dh) ** 0.5
    spec = pl.BlockSpec((1, 1, s, dh), lambda i, j: (i, j, 0, 0))
    return pl.pallas_call(
        functools.partial(_attention_kernel, scale=scale),
        grid=(b, h),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((b, h, s, dh), jnp.float32),
        interpret=True,
    )(q, k, v)
