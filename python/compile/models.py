"""L2: the model zoo substituting the paper's Table I networks.

Every forward pass is composed from the L1 Pallas kernels
(`fused_linear`, `softmax_bvsb`, `attention`) so the whole classifier
lowers into one HLO module per (model, batch) pair. Two families:

* MLP tiers — device models see a *lossy fixed projection* of the input
  (32/48/64 dims), which is what makes them genuinely less accurate than
  the server models on the hard tail, exactly like a MobileNetV2 vs. an
  InceptionV3 on the same image.
* ViT-style — the input is viewed as 8 tokens of 16 dims, embedded, run
  through pre-LN transformer blocks with the fused attention kernel, and
  mean-pooled. Substitutes MobileViT-x-small (device) / DeiT-Base
  (server).

Model names are the contract with the rust side (`rust/src/models/`):
dev_low, dev_mid, dev_high, dev_vit, srv_inception, srv_effnetb3,
srv_deit.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from . import data as D
from .kernels import attention, fused_linear, softmax_bvsb
from .kernels import ref


class KernelImpl:
    """Hot-compute ops via the L1 Pallas kernels (inference / AOT path)."""

    linear = staticmethod(fused_linear)
    softmax_bvsb = staticmethod(softmax_bvsb)
    attention = staticmethod(attention)


class RefImpl:
    """Pure-jnp ops (training path: pallas_call has no autodiff rules,
    and interpret-mode would be needlessly slow inside jax.grad).
    Mathematically identical to KernelImpl — pytest asserts allclose."""

    linear = staticmethod(lambda x, w, b, relu=True: ref.linear_ref(x, w, b, relu))
    softmax_bvsb = staticmethod(ref.softmax_bvsb_ref)
    attention = staticmethod(ref.attention_ref)


@dataclasses.dataclass(frozen=True)
class MlpSpec:
    name: str
    proj_dim: int | None  # lossy input projection (device tiers) or None
    hidden: tuple[int, ...]
    input_noise: float = 0.0  # train-time-only input jitter (regularizer)


@dataclasses.dataclass(frozen=True)
class VitSpec:
    name: str
    embed_dim: int
    heads: int
    blocks: int
    mlp_ratio: int = 2
    proj_dim: int | None = None  # lossy input projection (device tiers)


# The ladder: accuracy ordering must match Table I
#   dev_low < dev_vit < dev_mid < dev_high < srv_inception
#   < srv_effnetb3 < srv_deit
# Capacity/fidelity knobs are calibrated; measured accuracies are
# recorded by calibrate.py into artifacts/meta.json.
MODEL_SPECS: dict[str, MlpSpec | VitSpec] = {
    "dev_low": MlpSpec("dev_low", proj_dim=88, hidden=(96,)),
    "dev_mid": MlpSpec("dev_mid", proj_dim=104, hidden=(128,)),
    "dev_high": MlpSpec("dev_high", proj_dim=118, hidden=(176,)),
    "dev_vit": VitSpec("dev_vit", embed_dim=64, heads=4, blocks=2, proj_dim=104),
    "srv_inception": MlpSpec("srv_inception", proj_dim=None, hidden=(144, 144)),
    "srv_effnetb3": MlpSpec("srv_effnetb3", proj_dim=None, hidden=(512, 512)),
    "srv_deit": VitSpec("srv_deit", embed_dim=128, heads=8, blocks=3, mlp_ratio=3),
}

DEVICE_MODELS = ("dev_low", "dev_mid", "dev_high", "dev_vit")
SERVER_MODELS = ("srv_inception", "srv_effnetb3", "srv_deit")


# --------------------------------------------------------------------------
# Parameter construction
# --------------------------------------------------------------------------


def _glorot(key, shape):
    fan_in, fan_out = shape[0], shape[-1]
    scale = jnp.sqrt(2.0 / (fan_in + fan_out))
    return jax.random.normal(key, shape, dtype=jnp.float32) * scale


def init_mlp(spec: MlpSpec, key) -> dict:
    dims = [spec.proj_dim or D.INPUT_DIM, *spec.hidden, D.NUM_CLASSES]
    params: dict = {}
    if spec.proj_dim is not None:
        key, sub = jax.random.split(key)
        # The lossy projection is FROZEN (not trained): it models the
        # information loss of a small backbone, so training cannot
        # recover it.
        params["proj"] = _glorot(sub, (D.INPUT_DIM, spec.proj_dim))
    for i in range(len(dims) - 1):
        key, kw, kb = jax.random.split(key, 3)
        params[f"w{i}"] = _glorot(kw, (dims[i], dims[i + 1]))
        params[f"b{i}"] = jnp.zeros((dims[i + 1],), jnp.float32)
    params["_layers"] = len(dims) - 1  # static, stripped before jit
    return params


def init_vit(spec: VitSpec, key) -> dict:
    e = spec.embed_dim
    params: dict = {"_blocks": spec.blocks, "_heads": spec.heads}
    in_dim = D.INPUT_DIM
    if spec.proj_dim is not None:
        key, sub = jax.random.split(key)
        # Frozen lossy projection, as for the MLP device tiers.
        params["proj"] = _glorot(sub, (D.INPUT_DIM, spec.proj_dim))
        in_dim = spec.proj_dim
    key, sub = jax.random.split(key)
    # Patch-embed analogue: TOKEN_LEN learned full-width views of the
    # input vector (each token j = x @ W[:, j, :]), instead of slicing
    # the vector into lossy 16-dim chunks.
    params["embed_w"] = _glorot(sub, (in_dim, D.TOKEN_LEN * e))
    params["embed_b"] = jnp.zeros((D.TOKEN_LEN * e,), jnp.float32)
    key, sub = jax.random.split(key)
    params["pos"] = jax.random.normal(sub, (D.TOKEN_LEN, e), jnp.float32) * 0.02
    for blk in range(spec.blocks):
        key, kq, kk, kv, ko, k1, k2 = jax.random.split(key, 7)
        params[f"b{blk}_wq"] = _glorot(kq, (e, e))
        params[f"b{blk}_wk"] = _glorot(kk, (e, e))
        params[f"b{blk}_wv"] = _glorot(kv, (e, e))
        params[f"b{blk}_wo"] = _glorot(ko, (e, e))
        params[f"b{blk}_ln1_g"] = jnp.ones((e,), jnp.float32)
        params[f"b{blk}_ln1_b"] = jnp.zeros((e,), jnp.float32)
        params[f"b{blk}_ln2_g"] = jnp.ones((e,), jnp.float32)
        params[f"b{blk}_ln2_b"] = jnp.zeros((e,), jnp.float32)
        params[f"b{blk}_mlp_w1"] = _glorot(k1, (e, e * spec.mlp_ratio))
        params[f"b{blk}_mlp_b1"] = jnp.zeros((e * spec.mlp_ratio,), jnp.float32)
        params[f"b{blk}_mlp_w2"] = _glorot(k2, (e * spec.mlp_ratio, e))
        params[f"b{blk}_mlp_b2"] = jnp.zeros((e,), jnp.float32)
    params["final_ln_g"] = jnp.ones((e,), jnp.float32)
    params["final_ln_b"] = jnp.zeros((e,), jnp.float32)
    key, kh = jax.random.split(key)
    params["head_w"] = _glorot(kh, (e, D.NUM_CLASSES))
    params["head_b"] = jnp.zeros((D.NUM_CLASSES,), jnp.float32)
    return params


def init_params(name: str, seed: int = 0) -> dict:
    spec = MODEL_SPECS[name]
    key = jax.random.PRNGKey(seed ^ hash(name) & 0xFFFF)
    if isinstance(spec, MlpSpec):
        return init_mlp(spec, key)
    return init_vit(spec, key)


# --------------------------------------------------------------------------
# Forward passes (all hot compute through the Pallas kernels)
# --------------------------------------------------------------------------


def mlp_logits(params: dict, x: jax.Array, impl=KernelImpl) -> jax.Array:
    n_layers = int(params["_layers"])
    h = x
    if "proj" in params:
        # Frozen lossy projection: plain dot (not a trained hot-spot).
        h = jnp.dot(h, params["proj"])
    for i in range(n_layers):
        h = impl.linear(h, params[f"w{i}"], params[f"b{i}"], relu=i < n_layers - 1)
    return h


def _layer_norm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def vit_logits(params: dict, x: jax.Array, impl=KernelImpl) -> jax.Array:
    blocks, heads = int(params["_blocks"]), int(params["_heads"])
    bsz = x.shape[0]
    h = x
    if "proj" in params:
        # Frozen lossy projection (device-tier fidelity loss).
        h = jnp.dot(h, params["proj"])
    # Patch-embed analogue via the fused kernel: (B, in) -> (B, T*e).
    h = impl.linear(h, params["embed_w"], params["embed_b"], relu=False)
    e = h.shape[-1] // D.TOKEN_LEN
    h = h.reshape(bsz, D.TOKEN_LEN, e) + params["pos"][None]
    dh = e // heads
    zero_b = jnp.zeros((e,), jnp.float32)
    for blk in range(blocks):
        ln = _layer_norm(h, params[f"b{blk}_ln1_g"], params[f"b{blk}_ln1_b"])
        flat = ln.reshape(bsz * D.TOKEN_LEN, e)
        q = impl.linear(flat, params[f"b{blk}_wq"], zero_b, relu=False)
        k = impl.linear(flat, params[f"b{blk}_wk"], zero_b, relu=False)
        v = impl.linear(flat, params[f"b{blk}_wv"], zero_b, relu=False)

        def heads_view(t):
            return t.reshape(bsz, D.TOKEN_LEN, heads, dh).transpose(0, 2, 1, 3)

        att = impl.attention(heads_view(q), heads_view(k), heads_view(v))
        att = att.transpose(0, 2, 1, 3).reshape(bsz * D.TOKEN_LEN, e)
        proj = impl.linear(att, params[f"b{blk}_wo"], zero_b, relu=False)
        h = h + proj.reshape(bsz, D.TOKEN_LEN, e)
        ln = _layer_norm(h, params[f"b{blk}_ln2_g"], params[f"b{blk}_ln2_b"])
        flat = ln.reshape(bsz * D.TOKEN_LEN, e)
        m = impl.linear(flat, params[f"b{blk}_mlp_w1"], params[f"b{blk}_mlp_b1"], relu=True)
        m = impl.linear(m, params[f"b{blk}_mlp_w2"], params[f"b{blk}_mlp_b2"], relu=False)
        h = h + m.reshape(bsz, D.TOKEN_LEN, e)
    pooled = jnp.mean(h, axis=1)
    pooled = _layer_norm(pooled, params["final_ln_g"], params["final_ln_b"])
    return impl.linear(pooled, params["head_w"], params["head_b"], relu=False)


def logits_fn(name: str, impl=KernelImpl) -> Callable[[dict, jax.Array], jax.Array]:
    spec = MODEL_SPECS[name]
    base = mlp_logits if isinstance(spec, MlpSpec) else vit_logits
    return lambda params, x: base(params, x, impl=impl)


def forward(name: str, params: dict, x: jax.Array, impl=KernelImpl):
    """Full inference graph: logits -> fused softmax+BvSB.

    Returns (probs (B, K), bvsb (B,)). This is the function that aot.py
    lowers per batch size; the rust runtime computes top-1/correctness
    from `probs` and feeds `bvsb` to the forwarding decision function.
    """
    logits = logits_fn(name, impl)(params, x)
    probs, bvsb = impl.softmax_bvsb(logits)
    return probs, bvsb


def strip_static(params: dict) -> dict:
    """Split trainable arrays from static ints (for jax.grad/jit)."""
    return {k: v for k, v in params.items() if not k.startswith("_")}


def static_part(params: dict) -> dict:
    return {k: v for k, v in params.items() if k.startswith("_")}


# --------------------------------------------------------------------------
# Flat parameter vector (the AOT runtime ABI)
# --------------------------------------------------------------------------
#
# HLO *text* — the only interchange format the rust-side xla_extension
# 0.5.1 accepts — elides large constants ("constant({...})"), so weights
# cannot be baked into the module. Instead every artifact takes TWO
# runtime inputs: (x, flat_params); the flat vector's layout is fixed by
# sorted parameter names and exported as artifacts/<model>.params.bin.


def param_layout(params: dict) -> list[tuple[str, tuple[int, ...], int, int]]:
    """(name, shape, offset, size) for each trainable array, sorted."""
    layout = []
    offset = 0
    for k in sorted(strip_static(params)):
        shape = tuple(np.asarray(params[k]).shape)
        size = int(np.prod(shape)) if shape else 1
        layout.append((k, shape, offset, size))
        offset += size
    return layout


def flatten_params(params: dict) -> np.ndarray:
    """Concatenate trainable arrays in layout order (float32)."""
    return np.concatenate(
        [np.asarray(params[k], dtype=np.float32).ravel() for k, _, _, _ in param_layout(params)]
    )


def unflatten_params(flat: jax.Array, layout, statics: dict) -> dict:
    """Rebuild the params dict from a flat vector (traced inside jit)."""
    out: dict = dict(statics)
    for k, shape, offset, size in layout:
        out[k] = jax.lax.dynamic_slice(flat, (offset,), (size,)).reshape(shape)
    return out


# --------------------------------------------------------------------------
# Parameter (de)serialization — artifacts/params/<name>.npz
# --------------------------------------------------------------------------


def save_params(path: str, params: dict) -> None:
    arrays = {k: np.asarray(v) for k, v in strip_static(params).items()}
    statics = {f"__static_{k}": np.asarray(v) for k, v in static_part(params).items()}
    np.savez(path, **arrays, **statics)


def load_params(path: str) -> dict:
    raw = np.load(path)
    params: dict = {}
    for k in raw.files:
        if k.startswith("__static_"):
            params[k[len("__static_") :]] = int(raw[k])
        else:
            params[k] = jnp.asarray(raw[k])
    return params
