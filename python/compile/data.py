"""Synthetic ImageNet-val substitute (DESIGN.md §3).

The scheduler only ever consumes the joint distribution of
(light-model BvSB margin, light-correct, heavy-correct); we reproduce
that structure with a Gaussian-prototype classification problem whose
per-sample difficulty is drawn from a heavy-tailed distribution:

    x_i = mu_{y_i} + sigma_i * eps_i,   eps_i ~ N(0, I_d / sqrt(d))

Low-sigma samples are easy (every model gets them right, margins are
large); high-sigma samples are the "challenging" tail that the paper's
cascade forwards to the server. Splits mirror the paper's use of the
ImageNet validation set: 50 000 eval samples, of which the FIRST 10 000
are the offline calibration split (static thresholds, switching limits)
and the LAST 40 000 are the pool devices sample their 5 000-sample
streams from (§V-A). A disjoint 20 000-sample train split is used to
train the model substitutes at build time.
"""

from __future__ import annotations

import dataclasses
import struct

import numpy as np

# Dataset geometry (DESIGN.md §3: K=100 instead of 1000 keeps build-time
# training in seconds; BvSB structure is class-count independent).
INPUT_DIM = 128
NUM_CLASSES = 100
N_EVAL = 50_000
N_TRAIN = 40_000
N_CALIBRATION = 10_000  # first 10k of eval, as in the paper
TOKEN_LEN = 8  # ViT-style models view x as (8 tokens, 16 dims)
TOKEN_DIM = INPUT_DIM // TOKEN_LEN

# Difficulty distribution: lognormal noise scale. Tuned so the trained
# model ladder lands near the paper's Table I accuracy band
# (72% .. 83.4%); see calibrate.py for the measured values.
NOISE_LOG_MEAN = 0.78
NOISE_LOG_STD = 0.62

DATASET_MAGIC = b"MTPPDS01"


@dataclasses.dataclass
class Dataset:
    """An (x, y) classification set plus its difficulty scales."""

    x: np.ndarray  # (n, d) float32
    y: np.ndarray  # (n,) int32
    sigma: np.ndarray  # (n,) float32 per-sample noise scale

    @property
    def n(self) -> int:
        return self.x.shape[0]


def make_prototypes(seed: int = 7) -> np.ndarray:
    """Unit-norm class prototypes, near-orthogonal in R^128."""
    rng = np.random.default_rng(seed)
    protos = rng.standard_normal((NUM_CLASSES, INPUT_DIM)).astype(np.float32)
    protos /= np.linalg.norm(protos, axis=1, keepdims=True)
    return protos


def sample_dataset(protos: np.ndarray, n: int, seed: int) -> Dataset:
    rng = np.random.default_rng(seed)
    y = rng.integers(0, NUM_CLASSES, size=n).astype(np.int32)
    sigma = rng.lognormal(NOISE_LOG_MEAN, NOISE_LOG_STD, size=n).astype(np.float32)
    eps = rng.standard_normal((n, INPUT_DIM)).astype(np.float32) / np.sqrt(INPUT_DIM)
    x = protos[y] + sigma[:, None] * eps
    return Dataset(x=x.astype(np.float32), y=y, sigma=sigma)


def make_train_set(seed: int = 11) -> Dataset:
    return sample_dataset(make_prototypes(), N_TRAIN, seed)


def make_eval_set(seed: int = 13) -> Dataset:
    """The 50k 'validation set'. Deterministic across builds."""
    return sample_dataset(make_prototypes(), N_EVAL, seed)


def calibration_slice(ds: Dataset) -> Dataset:
    return Dataset(
        x=ds.x[:N_CALIBRATION], y=ds.y[:N_CALIBRATION], sigma=ds.sigma[:N_CALIBRATION]
    )


def eval_pool_slice(ds: Dataset) -> Dataset:
    return Dataset(
        x=ds.x[N_CALIBRATION:], y=ds.y[N_CALIBRATION:], sigma=ds.sigma[N_CALIBRATION:]
    )


def write_dataset(path: str, ds: Dataset) -> None:
    """Binary layout consumed by rust/src/data/dataset.rs:

    magic "MTPPDS01" | u32 n | u32 d | u32 k |
    f32 x[n*d] row-major | i32 y[n] | f32 sigma[n]   (all little-endian)
    """
    with open(path, "wb") as f:
        f.write(DATASET_MAGIC)
        f.write(struct.pack("<III", ds.n, ds.x.shape[1], NUM_CLASSES))
        f.write(ds.x.astype("<f4").tobytes())
        f.write(ds.y.astype("<i4").tobytes())
        f.write(ds.sigma.astype("<f4").tobytes())


def read_dataset(path: str) -> Dataset:
    with open(path, "rb") as f:
        magic = f.read(8)
        assert magic == DATASET_MAGIC, f"bad magic {magic!r}"
        n, d, k = struct.unpack("<III", f.read(12))
        assert k == NUM_CLASSES
        x = np.frombuffer(f.read(4 * n * d), dtype="<f4").reshape(n, d)
        y = np.frombuffer(f.read(4 * n), dtype="<i4")
        sigma = np.frombuffer(f.read(4 * n), dtype="<f4")
    return Dataset(x=x.copy(), y=y.copy(), sigma=sigma.copy())
