"""AOT build driver: train -> calibrate -> lower -> emit artifacts.

Run once via `make artifacts` (no-op if artifacts are current):

    cd python && python -m compile.aot --out-dir ../artifacts

Outputs (the full contract with the rust side):

    artifacts/
      dataset.bin                  50k eval set (data.py binary format)
      meta.json                    accuracies, static thresholds,
                                   switching limits (calibrate.py)
      params/<model>.npz           trained parameters (build cache)
      <model>_b<batch>.hlo.txt     one HLO-text module per (model, batch)
      expected/<model>.json        first-100-sample oracle outputs for
                                   rust integration tests

HLO **text** is the interchange format — NOT `.serialize()`: the `xla`
crate's xla_extension 0.5.1 rejects jax>=0.5 protos whose instruction
ids exceed INT_MAX; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from . import calibrate as C
from . import data as D
from . import models as M
from . import train as T

# Batch-size grid B = {1, 2, 4, 8, 16, 32, 64} (paper §V-A). Device
# models additionally get a large precompute batch used by the rust
# output-cache builder.
SERVER_BATCHES = (1, 2, 4, 8, 16, 32, 64)
DEVICE_BATCHES = (1, 64)
PRECOMPUTE_BATCH = 64


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_model(name: str, params: dict, batch: int) -> str:
    """Lower forward(name, params, x[batch]) to HLO text.

    The module takes TWO runtime inputs: (x, flat_params). Weights
    cannot ride inside the module because HLO *text* (the only
    interchange format xla_extension 0.5.1 accepts) elides large
    constants; the rust runtime feeds the flat vector exported to
    artifacts/<model>.params.bin (see models.param_layout for the
    deterministic layout).
    """
    layout = M.param_layout(params)
    statics = M.static_part(params)
    n_flat = sum(size for _, _, _, size in layout)
    x_spec = jax.ShapeDtypeStruct((batch, D.INPUT_DIM), jax.numpy.float32)
    p_spec = jax.ShapeDtypeStruct((n_flat,), jax.numpy.float32)

    def fn(x, flat):
        rebuilt = M.unflatten_params(flat, layout, statics)
        probs, bvsb = M.forward(name, rebuilt, x, impl=M.KernelImpl)
        return probs, bvsb

    lowered = jax.jit(fn).lower(x_spec, p_spec)
    return to_hlo_text(lowered)


def batches_for(name: str) -> tuple[int, ...]:
    return SERVER_BATCHES if name in M.SERVER_MODELS else DEVICE_BATCHES


def emit_expected(name: str, params: dict, ev: D.Dataset, out_path: str) -> None:
    """Oracle outputs on the first 100 eval samples (rust integration
    tests compare PJRT-executed artifacts against these)."""
    x = ev.x[:100]
    probs, bvsb = M.forward(name, params, x, impl=M.KernelImpl)
    probs = np.asarray(probs)
    record = {
        "top1": np.argmax(probs, axis=1).tolist(),
        "bvsb": np.round(np.asarray(bvsb), 6).tolist(),
        "p_top1": np.round(probs.max(axis=1), 6).tolist(),
    }
    with open(out_path, "w") as f:
        json.dump(record, f)


def build(out_dir: str, log=print) -> None:
    os.makedirs(out_dir, exist_ok=True)
    os.makedirs(os.path.join(out_dir, "expected"), exist_ok=True)

    log("[aot] dataset")
    ev = D.make_eval_set()
    ds_path = os.path.join(out_dir, "dataset.bin")
    if not os.path.exists(ds_path):
        D.write_dataset(ds_path, ev)

    log("[aot] train (cached under params/)")
    zoo = T.train_all(os.path.join(out_dir, "params"), log=log)

    log("[aot] calibrate")
    meta = C.calibrate(zoo, log=log)

    log("[aot] lower models to HLO text")
    artifact_index = {}
    param_files = {}
    for name, params in zoo.items():
        # Export the flat parameter vector the artifacts consume.
        flat = M.flatten_params(params)
        pfile = f"{name}.params.bin"
        flat.astype("<f4").tofile(os.path.join(out_dir, pfile))
        param_files[name] = {"file": pfile, "len": int(flat.size)}
        entries = []
        for batch in batches_for(name):
            fname = f"{name}_b{batch}.hlo.txt"
            path = os.path.join(out_dir, fname)
            if not os.path.exists(path):
                text = lower_model(name, params, batch)
                with open(path, "w") as f:
                    f.write(text)
                log(f"  [{name}] b={batch}: {len(text)} chars")
            entries.append({"batch": batch, "file": fname})
        artifact_index[name] = entries
        emit_expected(name, params, ev, os.path.join(out_dir, "expected", f"{name}.json"))
    meta["artifacts"] = artifact_index
    meta["param_files"] = param_files
    meta["batches"] = {
        "server": list(SERVER_BATCHES),
        "device": list(DEVICE_BATCHES),
        "precompute": PRECOMPUTE_BATCH,
    }

    C.write_meta(os.path.join(out_dir, "meta.json"), meta)
    log(f"[aot] wrote {os.path.join(out_dir, 'meta.json')}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    build(args.out_dir)


if __name__ == "__main__":
    main()
