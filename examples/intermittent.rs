//! Intermittent device participation (Figs 19/20 shape): 20 devices,
//! 50% offline probability, dynamic vs static thresholds; prints the
//! time-series trace.
//!
//! ```sh
//! cargo run --release --example intermittent
//! ```

use multitascpp::config::scenario::{Intermittent, Scenario, SchedulerKind};
use multitascpp::experiments::Ctx;
use multitascpp::models::Tier;

fn main() -> anyhow::Result<()> {
    multitascpp::util::logging::init();
    let artifacts = multitascpp::config::SystemConfig::locate_artifacts();
    let mut ctx = Ctx::load(&artifacts, std::path::Path::new("results"), true)?;

    for (label, sched, initial_threshold) in [
        (
            "dynamic threshold (MultiTASC++)",
            SchedulerKind::MultiTascPP,
            None,
        ),
        ("static threshold 0.35", SchedulerKind::Static, Some(0.35)),
    ] {
        let mut scn = Scenario::homogeneous(Tier::Low, 20, "srv_effnetb3")
            .with_scheduler(sched)
            .with_slo(150.0)
            .with_seed(1)
            .with_samples(2500)
            .with_intermittent(Intermittent::default());
        scn.initial_threshold = initial_threshold;
        let m = ctx.run(&scn)?;
        println!("\n== {label} ==");
        println!(
            "overall SR {:.2}%  accuracy {:.2}%  makespan {:.1}s",
            m.overall.satisfaction_rate(),
            m.overall.accuracy() * 100.0,
            m.makespan_s
        );
        println!(
            "{:>7} {:>7} {:>10} {:>8} {:>8} {:>7}",
            "t (s)", "active", "threshold", "SR %", "acc %", "queue"
        );
        for p in m.trace.iter().step_by(8) {
            println!(
                "{:>7.1} {:>7} {:>10.3} {:>8.1} {:>8.2} {:>7}",
                p.t_s,
                p.active_devices,
                p.mean_threshold,
                p.running_sr,
                p.running_acc * 100.0,
                p.queue_len
            );
        }
    }
    Ok(())
}
