//! §IV-E server model switching demo (Figs 17/18 shape): MultiTASC++
//! with the InceptionV3 ⇄ EfficientNetB3 ladder enabled, versus the
//! same scheduler pinned to the initial model.
//!
//! ```sh
//! cargo run --release --example model_switching
//! ```

use multitascpp::config::scenario::{Scenario, SchedulerKind};
use multitascpp::experiments::Ctx;
use multitascpp::models::Tier;

fn main() -> anyhow::Result<()> {
    multitascpp::util::logging::init();
    let artifacts = multitascpp::config::SystemConfig::locate_artifacts();
    let mut ctx = Ctx::load(&artifacts, std::path::Path::new("results"), true)?;

    println!("model switching: init srv_inception, 150 ms SLO, low-tier devices\n");
    println!(
        "{:>8} {:>10} {:>8} {:>8} {:>22}",
        "devices", "switching", "SR %", "acc %", "batches (inc/eff)"
    );
    for &n in &[2usize, 6, 10, 14, 18] {
        for switching in [true, false] {
            let scn = Scenario::homogeneous(Tier::Low, n, "srv_inception")
                .with_scheduler(SchedulerKind::MultiTascPP)
                .with_slo(150.0)
                .with_samples(2500)
                .with_switching(switching);
            let m = ctx.run(&scn)?;
            let inc = m.server_model_batches.get("srv_inception").copied().unwrap_or(0);
            let eff = m.server_model_batches.get("srv_effnetb3").copied().unwrap_or(0);
            println!(
                "{:>8} {:>10} {:>8.2} {:>8.2} {:>15}/{}",
                n,
                if switching { "on" } else { "off" },
                m.overall.satisfaction_rate(),
                m.overall.accuracy() * 100.0,
                inc,
                eff
            );
        }
    }
    println!("\nwith switching ON and few devices, the scheduler should migrate");
    println!("batches to the heavier EfficientNetB3 for extra accuracy (Fig 17).");
    Ok(())
}
