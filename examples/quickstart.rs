//! Quickstart: the full three-layer stack on a small real workload.
//!
//! Loads the AOT artifacts (JAX/Pallas-lowered HLO), runs a 5-device
//! cascade with REAL PJRT execution on the request path (no output
//! cache), and prints the paper's headline metrics.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use multitascpp::config::scenario::{Scenario, SchedulerKind};
use multitascpp::experiments::Ctx;
use multitascpp::models::Tier;

fn main() -> anyhow::Result<()> {
    multitascpp::util::logging::init();
    let artifacts = multitascpp::config::SystemConfig::locate_artifacts();
    let ctx = Ctx::load(&artifacts, std::path::Path::new("results"), true)?;

    let scn = Scenario::homogeneous(Tier::Low, 5, "srv_inception")
        .with_scheduler(SchedulerKind::MultiTascPP)
        .with_slo(150.0)
        .with_samples(400);

    println!("quickstart: 5 low-tier devices -> srv_inception, 150 ms SLO");
    println!("(real PJRT execution on every sample — no output cache)\n");
    let t0 = std::time::Instant::now();
    let m = ctx.run_real(&scn)?;
    println!(
        "samples          {:>8}\nSLO satisfaction {:>8.2} %\ncascade accuracy {:>8.2} %\nforwarded        {:>8.2} %",
        m.overall.samples,
        m.overall.satisfaction_rate(),
        m.overall.accuracy() * 100.0,
        m.overall.forward_rate() * 100.0,
    );
    println!(
        "goodput          {:>8.1} samples/s (virtual time)\nreal PJRT compute{:>8.0} ms for the whole run\nwall time        {:>8.2} s",
        m.throughput_satisfied(),
        m.real_compute_ms,
        t0.elapsed().as_secs_f64(),
    );
    // The device-only accuracy is the floor the cascade must beat.
    let dev_acc = ctx.registry.model("dev_low")?.acc_eval_pool * 100.0;
    println!(
        "\ndevice-only accuracy would be {dev_acc:.2} % — the cascade gained {:+.2} pp",
        m.overall.accuracy() * 100.0 - dev_acc
    );
    Ok(())
}
