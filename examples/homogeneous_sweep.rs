//! Homogeneous scalability sweep (the paper's Figs 4-6 shape at demo
//! scale): all three schedulers, rising device counts, one SLO.
//!
//! ```sh
//! cargo run --release --example homogeneous_sweep
//! ```

use multitascpp::config::scenario::{Scenario, SchedulerKind};
use multitascpp::experiments::Ctx;
use multitascpp::models::Tier;

fn main() -> anyhow::Result<()> {
    multitascpp::util::logging::init();
    let artifacts = multitascpp::config::SystemConfig::locate_artifacts();
    let mut ctx = Ctx::load(&artifacts, std::path::Path::new("results"), true)?;

    println!("homogeneous sweep: low-tier devices -> srv_inception, 150 ms SLO\n");
    println!(
        "{:>8} {:>14} {:>8} {:>8} {:>10}",
        "devices", "scheduler", "SR %", "acc %", "goodput/s"
    );
    for &n in &[2usize, 10, 25, 50, 80] {
        for kind in [
            SchedulerKind::MultiTascPP,
            SchedulerKind::MultiTasc,
            SchedulerKind::Static,
        ] {
            let scn = Scenario::homogeneous(Tier::Low, n, "srv_inception")
                .with_scheduler(kind)
                .with_slo(150.0)
                .with_samples(2000);
            let m = ctx.run(&scn)?;
            println!(
                "{:>8} {:>14} {:>8.2} {:>8.2} {:>10.1}",
                n,
                kind.name(),
                m.overall.satisfaction_rate(),
                m.overall.accuracy() * 100.0,
                m.throughput_satisfied()
            );
        }
    }
    Ok(())
}
