//! Replicated server pool with pluggable queue disciplines.
//!
//! Runs an overloaded, mixed-criticality heterogeneous population
//! (low tier: tight 100 ms SLO; high tier: relaxed 400 ms) against
//! FIFO / EDF / tier-WFQ server queues at 1 and 2 replicas, plus an
//! admission-control (shedding) variant, and prints overall and
//! per-tier SLO satisfaction.
//!
//! ```sh
//! make artifacts && cargo run --release --example replicated_server
//! ```

use multitascpp::config::scenario::{QueueKind, Scenario, SchedulerKind};
use multitascpp::experiments::Ctx;
use multitascpp::models::Tier;
use multitascpp::sim::Overrides;

fn main() -> anyhow::Result<()> {
    multitascpp::util::logging::init();
    let artifacts = multitascpp::config::SystemConfig::locate_artifacts();
    let mut ctx = Ctx::load(&artifacts, std::path::Path::new("results"), true)?;

    let base = || {
        Scenario::heterogeneous(48, "srv_inception")
            .with_scheduler(SchedulerKind::Static)
            .with_slo(150.0)
            .with_tier_slo(Tier::Low, 100.0)
            .with_tier_slo(Tier::High, 400.0)
            .with_samples(1500)
            .with_seed(0)
    };

    println!(
        "{:<22} {:>8} {:>8} {:>8} {:>8} {:>8} {:>7}",
        "configuration", "SR %", "low SR", "mid SR", "high SR", "shed %", "batches"
    );
    for (label, queue, replicas, shed) in [
        ("fifo x1 (seed)", QueueKind::Fifo, 1usize, false),
        ("edf x1", QueueKind::Edf, 1, false),
        ("tier-wfq x1", QueueKind::TierWfq, 1, false),
        ("fifo x2", QueueKind::Fifo, 2, false),
        ("edf x2", QueueKind::Edf, 2, false),
        ("edf x1 + shed", QueueKind::Edf, 1, true),
    ] {
        let scn = base()
            .with_queue(queue)
            .with_replicas(replicas)
            .with_shed(shed);
        let m = ctx.run(&scn, &Overrides::default())?;
        let tier_sr = |t: Tier| {
            m.tier(t)
                .map(|a| a.satisfaction_rate())
                .unwrap_or(f64::NAN)
        };
        let batches: usize = m.per_server_batches.iter().sum();
        println!(
            "{:<22} {:>8.2} {:>8.2} {:>8.2} {:>8.2} {:>8.2} {:>7}",
            label,
            m.overall.satisfaction_rate(),
            tier_sr(Tier::Low),
            tier_sr(Tier::Mid),
            tier_sr(Tier::High),
            100.0 * m.shed_rate(),
            batches
        );
    }
    println!(
        "\nsee `mtpp sim --servers N --queue fifo|edf|tier-wfq [--shed]` and \
         `mtpp experiment replicas` for the full sweep"
    );
    Ok(())
}
