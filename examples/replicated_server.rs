//! Replicated server pool with pluggable queue disciplines.
//!
//! Loads the shipped `edf-tight-slo` preset (overloaded
//! mixed-criticality heterogeneous population: low tier at a tight
//! 100 ms SLO, high tier relaxed to 400 ms) and sweeps queue
//! discipline x replica count x shedding through declarative
//! `ScenarioSpec::set` overrides — the same dotted paths
//! `mtpp sim --set` takes — printing overall and per-tier SLO
//! satisfaction.
//!
//! ```sh
//! make artifacts && cargo run --release --example replicated_server
//! ```

use multitascpp::config::spec::ScenarioSpec;
use multitascpp::experiments::Ctx;
use multitascpp::models::Tier;

fn main() -> anyhow::Result<()> {
    multitascpp::util::logging::init();
    let artifacts = multitascpp::config::SystemConfig::locate_artifacts();
    let mut ctx = Ctx::load(&artifacts, std::path::Path::new("results"), true)?;

    let base = {
        let mut spec = ScenarioSpec::preset("edf-tight-slo")?;
        spec.set("devices", "hetero:48")?;
        spec
    };

    println!(
        "{:<22} {:>8} {:>8} {:>8} {:>8} {:>8} {:>7}",
        "configuration", "SR %", "low SR", "mid SR", "high SR", "shed %", "batches"
    );
    for (label, sets) in [
        ("fifo x1 (seed)", vec!["server.queue=fifo"]),
        ("edf x1", vec![]),
        ("tier-wfq x1", vec!["server.queue=tier-wfq"]),
        ("fifo x2", vec!["server.queue=fifo", "server.replicas=2"]),
        ("edf x2", vec!["server.replicas=2"]),
        ("edf x1 + shed", vec!["server.shed=true"]),
    ] {
        let mut spec = base.clone();
        for kv in sets {
            spec.apply_set(kv)?;
        }
        let m = ctx.run_spec(&spec)?;
        let tier_sr = |t: Tier| {
            m.tier(t)
                .map(|a| a.satisfaction_rate())
                .unwrap_or(f64::NAN)
        };
        let batches: usize = m.per_server_batches.iter().sum();
        println!(
            "{:<22} {:>8.2} {:>8.2} {:>8.2} {:>8.2} {:>8.2} {:>7}",
            label,
            m.overall.satisfaction_rate(),
            tier_sr(Tier::Low),
            tier_sr(Tier::Mid),
            tier_sr(Tier::High),
            100.0 * m.shed_rate(),
            batches
        );
    }
    println!(
        "\nsee `mtpp sim --preset edf-tight-slo --set server.replicas=N` and \
         `mtpp experiment replicas` for the full sweep"
    );
    Ok(())
}
