//! Live serving end-to-end: a real TCP leader (queue + dynamic batcher
//! + PJRT + MultiTASC++) and three device agents running their light
//! models through PJRT, exchanging frames over localhost — the whole
//! paper architecture in wall-clock time, python nowhere in sight.
//!
//! ```sh
//! cargo run --release --example live_serving
//! ```

use std::time::Duration;

use multitascpp::config::SystemConfig;
use multitascpp::data::Dataset;
use multitascpp::models::{Registry, Tier};
use multitascpp::net::{run_device, serve, DeviceOptions, ServeOptions};

fn main() -> anyhow::Result<()> {
    multitascpp::util::logging::init();
    let artifacts = SystemConfig::locate_artifacts();
    let registry = Registry::load(&artifacts)?;
    let ds = Dataset::load(&artifacts.join("dataset.bin"))?;
    let cfg = SystemConfig::default();
    let addr = "127.0.0.1:7671".to_string();

    // Leader on its own thread (it owns its own PJRT client).
    let srv_registry = registry.clone();
    let srv_addr = addr.clone();
    let leader = std::thread::spawn(move || {
        let cfg = SystemConfig::default();
        serve(
            srv_registry,
            &cfg,
            &ServeOptions {
                addr: srv_addr,
                server_model: "srv_inception".into(),
                answer_limit: 0,
                idle_timeout: Duration::from_secs(3),
                ..ServeOptions::default()
            },
        )
    });
    std::thread::sleep(Duration::from_millis(400)); // let it bind

    // Three devices, different tiers, each with its own PJRT client.
    let mut handles = Vec::new();
    for (i, tier) in [Tier::Low, Tier::Mid, Tier::High].into_iter().enumerate() {
        let registry = registry.clone();
        let ds = ds.clone();
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || {
            let cfg = SystemConfig::default();
            run_device(
                registry,
                &ds,
                &cfg,
                &DeviceOptions {
                    addr,
                    tier,
                    samples: 150,
                    seed: i as u64,
                    slo_ms: 150.0,
                    paced: false, // flat-out: demo finishes in seconds
                },
            )
        }));
    }

    let mut total_fwd = 0;
    for (i, h) in handles.into_iter().enumerate() {
        let report = h.join().expect("device thread panicked")?;
        total_fwd += report.forwarded;
        println!(
            "device {i}: {} samples, {} forwarded, SLO {:.1}%, final threshold {:.3}",
            report.samples,
            report.forwarded,
            100.0 * report.slo_satisfied as f64 / report.samples.max(1) as f64,
            report.final_threshold
        );
    }
    let answered = leader.join().expect("leader thread panicked")?;
    println!("\nleader answered {answered} heavy-model requests ({total_fwd} forwarded)");
    anyhow::ensure!(answered > 0, "no requests reached the server");
    println!("live serving OK");
    Ok(())
}
