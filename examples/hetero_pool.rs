//! Model-aware heterogeneous replica pool.
//!
//! Loads the shipped `edf-tight-slo` preset (the PR 1 replicated-server
//! workload: overloaded mixed-criticality population) as a declarative
//! `ScenarioSpec`, then swaps in each heterogeneous-pool server policy:
//! lowest-index vs model-aware dispatch over a mixed EfficientNetB3 +
//! InceptionV3 pool, slack-aware batch sizing, and cost-aware
//! autoscaling (the `hetero-pool-autoscale` preset is the standalone
//! version of the last row). Prints overall / per-tier SLO
//! satisfaction, per-replica batch counts, and the replica-seconds the
//! autoscaler kept parked.
//!
//! ```sh
//! make artifacts && cargo run --release --example hetero_pool
//! ```

use multitascpp::config::spec::ScenarioSpec;
use multitascpp::experiments::figures::hetero_pool_policies;
use multitascpp::experiments::Ctx;
use multitascpp::models::Tier;

fn main() -> anyhow::Result<()> {
    multitascpp::util::logging::init();
    let artifacts = multitascpp::config::SystemConfig::locate_artifacts();
    let mut ctx = Ctx::load(&artifacts, std::path::Path::new("results"), true)?;

    // Each row replaces the whole `server` section with its policy, so
    // only the preset's population / SLOs / stream length carry over.
    let base = {
        let mut spec = ScenarioSpec::preset("edf-tight-slo")?;
        spec.set("devices", "hetero:48")?;
        spec
    };

    println!(
        "{:<16} {:>8} {:>8} {:>8} {:>8} {:>12} {:>9}",
        "configuration", "SR %", "low SR", "mid SR", "high SR", "batches", "parked s"
    );
    for (label, policy) in hetero_pool_policies() {
        let mut spec = base.clone();
        spec.server = policy;
        let m = ctx.run_spec(&spec)?;
        let tier_sr = |t: Tier| {
            m.tier(t)
                .map(|a| a.satisfaction_rate())
                .unwrap_or(f64::NAN)
        };
        let batches: Vec<String> = m
            .per_server_batches
            .iter()
            .map(|b| b.to_string())
            .collect();
        println!(
            "{:<16} {:>8.2} {:>8.2} {:>8.2} {:>8.2} {:>12} {:>9.1}",
            label,
            m.overall.satisfaction_rate(),
            tier_sr(Tier::Low),
            tier_sr(Tier::Mid),
            tier_sr(Tier::High),
            batches.join("/"),
            m.parked_replica_seconds
        );
    }
    println!(
        "\nsee `mtpp sim --preset hetero-pool-autoscale` and \
         `mtpp experiment hetero-pool` for the full sweep"
    );
    Ok(())
}
