//! Model-aware heterogeneous replica pool.
//!
//! Runs the overloaded mixed-criticality population from the PR 1
//! replicated-server example against a mixed EfficientNetB3 +
//! InceptionV3 pool: lowest-index vs model-aware dispatch, slack-aware
//! batch sizing, and cost-aware autoscaling. Prints overall / per-tier
//! SLO satisfaction, per-replica batch counts, and the replica-seconds
//! the autoscaler kept parked.
//!
//! ```sh
//! make artifacts && cargo run --release --example hetero_pool
//! ```

use multitascpp::config::scenario::{Scenario, SchedulerKind};
use multitascpp::experiments::figures::hetero_pool_policies;
use multitascpp::experiments::Ctx;
use multitascpp::models::Tier;
use multitascpp::sim::Overrides;

fn main() -> anyhow::Result<()> {
    multitascpp::util::logging::init();
    let artifacts = multitascpp::config::SystemConfig::locate_artifacts();
    let mut ctx = Ctx::load(&artifacts, std::path::Path::new("results"), true)?;

    let base = || {
        Scenario::heterogeneous(48, "srv_inception")
            .with_scheduler(SchedulerKind::Static)
            .with_slo(150.0)
            .with_tier_slo(Tier::Low, 100.0)
            .with_tier_slo(Tier::High, 400.0)
            .with_samples(1500)
            .with_seed(0)
    };

    println!(
        "{:<16} {:>8} {:>8} {:>8} {:>8} {:>12} {:>9}",
        "configuration", "SR %", "low SR", "mid SR", "high SR", "batches", "parked s"
    );
    for (label, policy) in hetero_pool_policies() {
        let scn = base().with_server_policy(policy);
        let m = ctx.run(&scn, &Overrides::default())?;
        let tier_sr = |t: Tier| {
            m.tier(t)
                .map(|a| a.satisfaction_rate())
                .unwrap_or(f64::NAN)
        };
        let batches: Vec<String> = m
            .per_server_batches
            .iter()
            .map(|b| b.to_string())
            .collect();
        println!(
            "{:<16} {:>8.2} {:>8.2} {:>8.2} {:>8.2} {:>12} {:>9.1}",
            label,
            m.overall.satisfaction_rate(),
            tier_sr(Tier::Low),
            tier_sr(Tier::Mid),
            tier_sr(Tier::High),
            batches.join("/"),
            m.parked_replica_seconds
        );
    }
    println!(
        "\nsee `mtpp sim --server-models a,b --dispatch model-aware --slack-batch \
         [--autoscale]` and `mtpp experiment hetero-pool` for the full sweep"
    );
    Ok(())
}
