//! Heterogeneous sweep (Figs 11-14 shape): equal thirds of low / mid /
//! high devices sharing one server, per-tier metrics.
//!
//! ```sh
//! cargo run --release --example heterogeneous_sweep
//! ```

use multitascpp::config::scenario::{Scenario, SchedulerKind};
use multitascpp::experiments::Ctx;
use multitascpp::models::Tier;

fn main() -> anyhow::Result<()> {
    multitascpp::util::logging::init();
    let artifacts = multitascpp::config::SystemConfig::locate_artifacts();
    let mut ctx = Ctx::load(&artifacts, std::path::Path::new("results"), true)?;

    println!("heterogeneous sweep: 1/3 low, 1/3 mid, 1/3 high -> srv_effnetb3, 150 ms SLO\n");
    for &n in &[6usize, 18, 36, 60] {
        for kind in [SchedulerKind::MultiTascPP, SchedulerKind::Static] {
            let scn = Scenario::heterogeneous(n, "srv_effnetb3")
                .with_scheduler(kind)
                .with_slo(150.0)
                .with_samples(2000);
            let m = ctx.run(&scn)?;
            println!("{n} devices, {}:", kind.name());
            for tier in [Tier::Low, Tier::Mid, Tier::High] {
                if let Some(agg) = m.tier(tier) {
                    println!(
                        "  {:<5} SR {:>6.2}%  acc {:>6.2}%  fwd {:>5.1}%",
                        tier.name(),
                        agg.satisfaction_rate(),
                        agg.accuracy() * 100.0,
                        agg.forward_rate() * 100.0
                    );
                }
            }
        }
    }
    Ok(())
}
