//! Microbenchmarks of the L3 hot paths (custom harness; criterion is
//! unavailable offline). Run with `cargo bench --bench micro`.

use multitascpp::bench::{bench, black_box, BenchConfig};
use multitascpp::config::scenario::{Scenario, SchedulerKind};
use multitascpp::config::SystemConfig;
use multitascpp::data::dataset::Dataset;
use multitascpp::models::outputs::SyntheticOutputs;
use multitascpp::models::registry::test_meta_json;
use multitascpp::models::{Registry, Tier};
use multitascpp::scheduler::{MultiTascPP, Scheduler};
use multitascpp::sim::run_scenario;
use multitascpp::util::json::Json;
use multitascpp::util::prng::Rng;

fn main() {
    println!("== micro benches ==");
    let fast = BenchConfig {
        warmup: 3,
        samples: 20,
        iters_per_sample: 1000,
    };

    // Scheduler update rule (Eq. 4 + Alg. 1): the per-window cost that
    // must stay negligible next to inference.
    {
        let mut s = MultiTascPP::new(0.005);
        for d in 0..100 {
            s.register_device(d, Tier::Low, 0.5, 95.0);
        }
        let mut i = 0usize;
        let r = bench("scheduler: on_sr_update (100 devices)", &fast, |_| {
            let sr = if i % 3 == 0 { 90.0 } else { 97.0 };
            black_box(s.on_sr_update(i % 100, sr));
            i += 1;
        });
        println!("  -> {:.0} updates/s\n", r.throughput(1.0));
    }

    // Event queue push/pop.
    {
        use multitascpp::sim::event::{Event, EventQueue};
        let r = bench("event queue: push+pop pair", &fast, |i| {
            let mut q = EventQueue::new();
            for j in 0..64 {
                q.push((i * 64 + j) as f64, Event::ServerBatchDone { server: 0 });
            }
            while let Some(e) = q.pop() {
                black_box(e);
            }
        });
        println!("  -> {:.0} events/s\n", r.throughput(128.0));
    }

    // PRNG.
    {
        let mut rng = Rng::new(7);
        let r = bench("prng: next_f64", &fast, |_| {
            black_box(rng.next_f64());
        });
        println!("  -> {:.0} draws/s\n", r.throughput(1.0));
    }

    // JSON parse of a meta.json-sized document.
    {
        let text = test_meta_json().to_string();
        let cfg = BenchConfig {
            warmup: 3,
            samples: 20,
            iters_per_sample: 100,
        };
        let r = bench(
            &format!("json: parse meta ({} bytes)", text.len()),
            &cfg,
            |_| {
                black_box(Json::parse(&text).unwrap());
            },
        );
        println!("  -> {:.1} MB/s\n", text.len() as f64 * r.throughput(1.0) / 1e6);
    }

    // End-to-end simulation throughput (cached provider): the number
    // that bounds every figure sweep.
    {
        let reg =
            Registry::from_meta(std::path::Path::new("/tmp/x"), &test_meta_json()).unwrap();
        let ds = Dataset::synthetic_for_tests(5000, 4, 10);
        let cfg = SystemConfig::default();
        let samples_per_run = 40 * 1000;
        let bench_cfg = BenchConfig {
            warmup: 1,
            samples: 8,
            iters_per_sample: 1,
        };
        let mut seed = 0u64;
        let r = bench("sim e2e: 40 devices x 1000 samples", &bench_cfg, |_| {
            let mut prov = SyntheticOutputs::new(
                ds.n,
                &[("dev_low", 0.72), ("srv_inception", 0.785)],
                seed,
            )
            .into_cached();
            seed += 1;
            let scn = Scenario::homogeneous(Tier::Low, 40, "srv_inception")
                .with_scheduler(SchedulerKind::MultiTascPP)
                .with_samples(1000)
                .with_seed(seed);
            black_box(run_scenario(&scn, &cfg, &reg, &ds, &mut prov).unwrap());
        });
        println!(
            "  -> {:.0} simulated samples/s\n",
            r.throughput(samples_per_run as f64)
        );
    }
}
