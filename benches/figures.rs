//! End-to-end benches: one timed run per paper table/figure family at
//! reduced sweep scale, exercising the full experiment pipeline
//! (registry + dataset + PJRT output caches + sim). Requires
//! `make artifacts`; skips gracefully when artifacts are absent.
//!
//! Run with `cargo bench --bench figures`.

use std::time::Instant;

use multitascpp::config::SystemConfig;
use multitascpp::experiments::{registry, Ctx};

fn main() {
    multitascpp::util::logging::init();
    let artifacts = SystemConfig::locate_artifacts();
    if !artifacts.join("meta.json").exists() {
        println!("figures bench: artifacts not found (run `make artifacts`) — skipping");
        return;
    }
    let results = std::path::Path::new("results/bench");
    let mut ctx = match Ctx::load(&artifacts, results, /*quick=*/ true) {
        Ok(c) => c,
        Err(e) => {
            println!("figures bench: context load failed ({e:#}) — skipping");
            return;
        }
    };
    println!("== end-to-end figure benches (quick sweeps) ==");
    let mut total = 0.0;
    for (id, desc, driver) in registry() {
        let t0 = Instant::now();
        if let Err(e) = driver(&mut ctx) {
            println!("{id:<10} FAILED: {e:#}");
            continue;
        }
        let dt = t0.elapsed().as_secs_f64();
        total += dt;
        println!(">> {id:<10} {dt:>8.2} s   ({desc})");
    }
    println!("total: {total:.1} s");
}
