//! PJRT runtime benches: real execute latency per (model, batch) next
//! to the calibrated T4 latency table — the L1/L2 perf evidence (the
//! CPU numbers are not expected to match a T4; the table gives the
//! translation). Requires `make artifacts`.
//!
//! Run with `cargo bench --bench runtime_exec`.

use multitascpp::bench::{bench, black_box, BenchConfig};
use multitascpp::config::latency::server_latency_model;
use multitascpp::config::SystemConfig;
use multitascpp::data::Dataset;
use multitascpp::models::Registry;
use multitascpp::runtime::Engine;

fn main() -> anyhow::Result<()> {
    multitascpp::util::logging::init();
    let artifacts = SystemConfig::locate_artifacts();
    if !artifacts.join("meta.json").exists() {
        println!("runtime bench: artifacts not found (run `make artifacts`) — skipping");
        return Ok(());
    }
    let registry = Registry::load(&artifacts)?;
    let ds = Dataset::load(&artifacts.join("dataset.bin"))?;
    let engine = Engine::new(registry)?;

    println!("== PJRT execute latency per (model, batch) ==");
    println!("(CPU PJRT here; 'T4 table' column is the calibrated virtual latency)\n");
    let cfg = BenchConfig {
        warmup: 3,
        samples: 15,
        iters_per_sample: 1,
    };
    for model in [
        "dev_low",
        "dev_mid",
        "dev_high",
        "dev_vit",
        "srv_inception",
        "srv_effnetb3",
        "srv_deit",
    ] {
        for batch in engine.registry().batches(model)? {
            let x = ds.gather(&(0..batch).collect::<Vec<_>>());
            let r = bench(&format!("{model} b={batch}"), &cfg, |_| {
                black_box(engine.infer(model, &x, batch).unwrap());
            });
            let table = if model.starts_with("srv_") {
                format!("{:>8.1} ms", server_latency_model(model).batch_ms(batch))
            } else {
                "      n/a".to_string()
            };
            println!(
                "    -> {:>9.0} samples/s real   T4 table {table}\n",
                r.throughput(batch as f64)
            );
        }
    }
    Ok(())
}
